//! Session lifecycle: a single online TD(lambda) learner owned by the
//! prediction service.
//!
//! A [`Session`] wraps the existing [`TdLambdaAgent`] over a boxed
//! [`ServableNet`], so *every* registered net family — `columnar`,
//! `constructive`, `ccn`, `tbptt`, `snap1` — opens, steps, snapshots and
//! restores through the same surface. Snapshots use a versioned envelope:
//!
//! ```json
//! {"v":2, "kind":"tbptt", "spec":{...}, "net":{...}, "td":{...}}
//! ```
//!
//! where `net` is [`PersistableNet::save`] output and restore routes
//! through [`NetRegistry::restore`] by the `kind` tag. Version-1
//! envelopes (PR 1's CCN-only format, no `kind` field) still restore
//! through a migration shim.
//!
//! Sessions whose net reports [`BatchCapability::Columnar`] can also
//! live inside a [`super::batch::ColumnarSessionBatch`];
//! [`Session::to_lane`] / [`Session::from_lane`] convert between the two
//! representations without loss (both paths step with identical
//! arithmetic). The capability is *discovered from the net*, never
//! pattern-matched from a learner kind, so future batchable families
//! only need to report their shape.

use crate::config::{build_servable, LearnerKind};
use crate::learn::{TdConfig, TdLambdaAgent, TdState};
use crate::nets::ccn::CcnNet;
use crate::nets::lstm_column::LstmColumn;
use crate::nets::normalizer::OnlineNormalizer;
use crate::nets::{BatchCapability, NetRegistry, PersistableNet, ServableNet};
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;

use super::batch::{ColumnarBatchSpec, ColumnarLane};

/// Everything needed to open (or re-open) a session.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub learner: LearnerKind,
    pub n_inputs: usize,
    pub td: TdConfig,
    /// normalizer epsilon
    pub eps: f32,
    /// column-initialization seed
    pub seed: u64,
}

impl SessionSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("learner", self.learner.to_json()),
            ("n_inputs", Json::Num(self.n_inputs as f64)),
            ("alpha", Json::Num(self.td.alpha as f64)),
            ("gamma", Json::Num(self.td.gamma as f64)),
            ("lambda", Json::Num(self.td.lambda as f64)),
            ("eps", Json::Num(self.eps as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        Some(Self {
            learner: LearnerKind::from_json(v.get("learner")?)?,
            n_inputs: v.get("n_inputs")?.as_usize()?,
            td: TdConfig {
                alpha: v.get("alpha")?.as_f64()? as f32,
                gamma: v.get("gamma")?.as_f64()? as f32,
                lambda: v.get("lambda")?.as_f64()? as f32,
            },
            eps: v.get("eps")?.as_f64()? as f32,
            seed: v.get("seed")?.as_f64()? as u64,
        })
    }
}

/// One live scalar session.
pub struct Session {
    spec: SessionSpec,
    agent: TdLambdaAgent<Box<dyn ServableNet>>,
}

/// Snapshot envelope version (bumped on breaking changes). v2 added the
/// `kind` tag and registry-routed restore; v1 (CCN family only) restores
/// through a migration shim in [`Session::from_snapshot`].
const SNAPSHOT_VERSION: f64 = 2.0;

impl Session {
    /// Open a fresh session for *any* registered learner kind.
    pub fn open(spec: SessionSpec) -> Result<Session, String> {
        if spec.n_inputs == 0 {
            return Err("session: n_inputs must be >= 1".into());
        }
        let net = build_servable(&spec.learner, spec.n_inputs, spec.eps, spec.seed)
            .map_err(|e| e.to_string())?;
        let agent = TdLambdaAgent::new(net, spec.td);
        Ok(Session { spec, agent })
    }

    pub fn spec(&self) -> &SessionSpec {
        &self.spec
    }

    /// The net's registered snapshot-kind tag.
    pub fn kind(&self) -> &'static str {
        self.agent.net.kind()
    }

    pub fn steps(&self) -> u64 {
        self.agent.steps()
    }

    /// The SoA batch shape this session can live in, discovered from the
    /// net's [`BatchCapability`]; `None` keeps the session scalar.
    pub fn columnar_batch_spec(&self) -> Option<ColumnarBatchSpec> {
        match self.agent.net.batch_capability() {
            BatchCapability::Columnar {
                n_inputs,
                d,
                eps,
                beta,
            } => Some(ColumnarBatchSpec {
                n_inputs,
                d,
                td: self.spec.td,
                eps,
                beta,
            }),
            BatchCapability::None => None,
        }
    }

    /// One online learning step: observation + cumulant in, prediction
    /// made at this step out.
    pub fn step(&mut self, x: &[f32], cumulant: f32) -> Result<f32, String> {
        if x.len() != self.spec.n_inputs {
            return Err(format!(
                "session expects {} inputs, got {}",
                self.spec.n_inputs,
                x.len()
            ));
        }
        Ok(self.agent.step(x, cumulant))
    }

    /// Prediction without learning. The recurrent state still advances
    /// (a prediction *consumes* the observation), but no TD update runs.
    pub fn predict(&mut self, x: &[f32]) -> Result<f32, String> {
        if x.len() != self.spec.n_inputs {
            return Err(format!(
                "session expects {} inputs, got {}",
                self.spec.n_inputs,
                x.len()
            ));
        }
        Ok(self.agent.predict_only(x))
    }

    /// Serialize the complete session (spec + net + TD state) into the
    /// v2 envelope. The snapshot restores to a session that continues
    /// bit-identically.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("v", Json::Num(SNAPSHOT_VERSION)),
            ("kind", Json::Str(self.kind().into())),
            ("spec", self.spec.to_json()),
            ("net", self.agent.net.save()),
            ("td", self.agent.td_state().to_json()),
        ])
    }

    /// Rebuild a session from [`Self::snapshot`] output (v2) or from a
    /// PR-1 v1 CCN snapshot (migration shim).
    pub fn from_snapshot(v: &Json) -> Result<Session, String> {
        let version = v
            .get("v")
            .and_then(|n| n.as_f64())
            .ok_or("snapshot: missing version")?;
        let spec = v
            .get("spec")
            .and_then(SessionSpec::from_json)
            .ok_or("snapshot: bad spec")?;
        let net_json = v.get("net").ok_or("snapshot: missing net")?;
        let net: Box<dyn ServableNet> = if version == 1.0 {
            // v1 envelopes carried no `kind` and covered the CCN family
            // only; their `net` payload is exactly CcnNet::from_json's
            // input, so migration is a direct restore.
            if !spec.learner.is_ccn_family() {
                return Err(format!(
                    "snapshot: v1 envelopes cover the CCN family only, \
                     spec says '{}'",
                    spec.learner.label()
                ));
            }
            Box::new(CcnNet::from_json(net_json)?)
        } else if version == SNAPSHOT_VERSION {
            let kind = v
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or("snapshot: missing kind")?;
            // the envelope kind must serialize-compatibly match the spec:
            // same registry family (the CCN corners share one format).
            let spec_family = NetRegistry::family(spec.learner.kind())
                .ok_or("snapshot: spec learner is not registered")?;
            if NetRegistry::family(kind) != Some(spec_family) {
                return Err(format!(
                    "snapshot: kind '{kind}' does not match spec learner '{}'",
                    spec.learner.label()
                ));
            }
            NetRegistry::restore(kind, net_json)?
        } else {
            return Err(format!("snapshot: unsupported version {version}"));
        };
        if net.n_inputs() != spec.n_inputs {
            return Err("snapshot: net/spec input width mismatch".into());
        }
        let td = v
            .get("td")
            .and_then(TdState::from_json)
            .ok_or("snapshot: bad td state")?;
        let mut agent = TdLambdaAgent::new(net, spec.td);
        agent.set_td_state(td)?;
        Ok(Session { spec, agent })
    }

    /// Extract this session's state as a batch lane. Errors for sessions
    /// without [`BatchCapability::Columnar`]. The [`ColumnarLane`]
    /// interchange format is stride-independent: the batch writes it
    /// into (and reads it out of) its capacity-padded arrays without the
    /// scalar side ever seeing the padding.
    pub fn to_lane(&self) -> Result<ColumnarLane, String> {
        let d = match self.agent.net.batch_capability() {
            BatchCapability::Columnar { d, .. } => d,
            BatchCapability::None => {
                return Err("session's net reports no batch capability".into())
            }
        };
        let net = self
            .agent
            .net
            .as_any()
            .downcast_ref::<CcnNet>()
            .ok_or("columnar batch capability implies a CCN-family net")?;
        let columns: Vec<LstmColumn> =
            (0..d).map(|k| net.column(0, k).clone()).collect();
        let (mu, var, denom) = net.stage_norm(0).state();
        Ok(ColumnarLane {
            columns,
            norm_mu: mu.to_vec(),
            norm_var: var.to_vec(),
            norm_denom: denom.to_vec(),
            td: self.agent.td_state(),
        })
    }

    /// Rebuild a scalar session from a batch lane (inverse of
    /// [`Self::to_lane`]; `batch_spec` is the shape of the batch the lane
    /// lived in). The columnar net never consumes its rng after
    /// construction, so a fresh stream seeded from the spec is equivalent
    /// to the original.
    pub fn from_lane(
        spec: SessionSpec,
        batch_spec: &ColumnarBatchSpec,
        lane: &ColumnarLane,
    ) -> Result<Session, String> {
        let d = batch_spec.d;
        if lane.columns.len() != d {
            return Err(format!(
                "lane has {} columns, batch wants {d}",
                lane.columns.len()
            ));
        }
        let cfg = crate::nets::ccn::CcnConfig {
            n_inputs: batch_spec.n_inputs,
            total_features: d,
            features_per_stage: d,
            steps_per_stage: u64::MAX,
            init_scale: 1.0,
            norm_eps: batch_spec.eps,
            norm_beta: batch_spec.beta,
        };
        let norm = OnlineNormalizer::from_state(
            batch_spec.beta,
            batch_spec.eps,
            lane.norm_mu.clone(),
            lane.norm_var.clone(),
            lane.norm_denom.clone(),
        )
        .ok_or("lane normalizer state inconsistent")?;
        let net = CcnNet::from_parts(
            cfg,
            vec![(lane.columns.clone(), norm)],
            lane.td.steps,
            1,
            false,
            Xoshiro256::seed_from_u64(spec.seed),
        )?;
        let mut agent =
            TdLambdaAgent::new(Box::new(net) as Box<dyn ServableNet>, spec.td);
        agent.set_td_state(lane.td.clone())?;
        Ok(Session { spec, agent })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn columnar_spec() -> SessionSpec {
        SessionSpec {
            learner: LearnerKind::Columnar { d: 4 },
            n_inputs: 3,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            seed: 7,
        }
    }

    fn spec_for(learner: LearnerKind) -> SessionSpec {
        SessionSpec {
            learner,
            ..columnar_spec()
        }
    }

    fn drive(s: &mut Session, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let x: Vec<f32> = (0..s.spec().n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let c = rng.uniform(-0.5, 0.5);
            ys.push(s.step(&x, c).unwrap());
        }
        ys
    }

    #[test]
    fn open_accepts_every_registered_kind() {
        for learner in [
            LearnerKind::Columnar { d: 4 },
            LearnerKind::Constructive {
                total: 4,
                steps_per_stage: 50,
            },
            LearnerKind::Ccn {
                total: 4,
                per_stage: 2,
                steps_per_stage: 50,
            },
            LearnerKind::Tbptt { d: 3, k: 6 },
            LearnerKind::Snap1 { d: 3 },
        ] {
            let kind = learner.kind();
            let mut s = Session::open(spec_for(learner)).unwrap();
            assert_eq!(s.kind(), kind);
            assert!(s.step(&[0.1, 0.2, 0.3], 0.0).unwrap().is_finite());
        }
    }

    #[test]
    fn open_rejects_zero_inputs() {
        let mut spec = columnar_spec();
        spec.n_inputs = 0;
        assert!(Session::open(spec).is_err());
    }

    #[test]
    fn step_checks_observation_width() {
        let mut s = Session::open(columnar_spec()).unwrap();
        assert!(s.step(&[0.0, 0.0], 0.0).is_err());
        assert!(s.step(&[0.0, 0.0, 0.0], 0.0).is_ok());
    }

    #[test]
    fn batch_capability_is_columnar_only() {
        let s = Session::open(columnar_spec()).unwrap();
        assert!(s.columnar_batch_spec().is_some());
        for learner in [
            LearnerKind::Ccn {
                total: 4,
                per_stage: 2,
                steps_per_stage: 50,
            },
            LearnerKind::Tbptt { d: 2, k: 4 },
            LearnerKind::Snap1 { d: 2 },
        ] {
            let s = Session::open(spec_for(learner)).unwrap();
            assert!(s.columnar_batch_spec().is_none(), "{}", s.kind());
            assert!(s.to_lane().is_err());
        }
    }

    #[test]
    fn snapshot_restore_continues_identically_for_every_kind() {
        for learner in [
            LearnerKind::Columnar { d: 4 },
            LearnerKind::Constructive {
                total: 4,
                steps_per_stage: 120,
            },
            LearnerKind::Tbptt { d: 3, k: 7 },
            LearnerKind::Snap1 { d: 3 },
        ] {
            let mut s = Session::open(spec_for(learner)).unwrap();
            drive(&mut s, 400, 1);
            let snap = s.snapshot();
            assert_eq!(snap.get("v"), Some(&Json::Num(2.0)));
            assert_eq!(
                snap.get("kind").and_then(|k| k.as_str()),
                Some(s.kind())
            );
            // round-trip through text to exercise the full codec
            let mut restored =
                Session::from_snapshot(&Json::parse(&snap.dump()).unwrap())
                    .unwrap_or_else(|e| panic!("{}: {e}", s.kind()));
            assert_eq!(restored.steps(), s.steps());
            assert_eq!(restored.kind(), s.kind());
            let a = drive(&mut s, 200, 2);
            let b = drive(&mut restored, 200, 2);
            assert_eq!(a, b, "{} must continue identically", s.kind());
        }
    }

    #[test]
    fn snapshot_restore_works_for_growing_ccn() {
        let spec = SessionSpec {
            learner: LearnerKind::Ccn {
                total: 6,
                per_stage: 2,
                steps_per_stage: 120,
            },
            n_inputs: 3,
            td: TdConfig::default(),
            eps: 0.01,
            seed: 3,
        };
        let mut s = Session::open(spec).unwrap();
        drive(&mut s, 150, 4); // past one stage boundary
        let snap = s.snapshot();
        let mut restored = Session::from_snapshot(&snap).unwrap();
        // continue across the *next* boundary too: the restored rng must
        // initialize the new stage's columns identically
        let a = drive(&mut s, 200, 5);
        let b = drive(&mut restored, 200, 5);
        assert_eq!(a, b, "growth after restore must match");
    }

    #[test]
    fn v1_ccn_snapshot_restores_through_migration_shim() {
        let mut s = Session::open(columnar_spec()).unwrap();
        drive(&mut s, 300, 8);
        // rewrite the v2 envelope into the exact shape PR 1 wrote:
        // {"v":1,"spec","net","td"} with no "kind" field.
        let mut o = match s.snapshot() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("v".into(), Json::Num(1.0));
        o.remove("kind");
        let mut restored = Session::from_snapshot(&Json::Obj(o)).unwrap();
        let a = drive(&mut s, 100, 9);
        let b = drive(&mut restored, 100, 9);
        assert_eq!(a, b, "v1 shim must restore losslessly");
    }

    #[test]
    fn v1_shim_rejects_dense_baselines() {
        let mut s = Session::open(spec_for(LearnerKind::Tbptt { d: 2, k: 4 }))
            .unwrap();
        drive(&mut s, 20, 1);
        let mut o = match s.snapshot() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("v".into(), Json::Num(1.0));
        o.remove("kind");
        let err = Session::from_snapshot(&Json::Obj(o)).unwrap_err();
        assert!(err.contains("v1"), "{err}");
    }

    #[test]
    fn lane_roundtrip_continues_identically() {
        let mut s = Session::open(columnar_spec()).unwrap();
        drive(&mut s, 300, 9);
        let batch_spec = s.columnar_batch_spec().unwrap();
        let lane = s.to_lane().unwrap();
        let mut back =
            Session::from_lane(s.spec().clone(), &batch_spec, &lane).unwrap();
        let a = drive(&mut s, 150, 10);
        let b = drive(&mut back, 150, 10);
        assert_eq!(a, b, "lane extraction must be lossless");
    }

    #[test]
    fn restore_rejects_corrupted_snapshots() {
        let s = Session::open(columnar_spec()).unwrap();
        let snap = s.snapshot();
        // wrong version
        let mut o = match snap.clone() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("v".into(), Json::Num(99.0));
        assert!(Session::from_snapshot(&Json::Obj(o)).is_err());
        // missing net
        let mut o = match snap.clone() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.remove("net");
        assert!(Session::from_snapshot(&Json::Obj(o)).is_err());
        // kind from a different family than the spec
        let mut o = match snap {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("kind".into(), Json::Str("tbptt".into()));
        let err = Session::from_snapshot(&Json::Obj(o)).unwrap_err();
        assert!(err.contains("does not match"), "{err}");
    }
}
