//! Session lifecycle: a single online TD(lambda) learner owned by the
//! prediction service.
//!
//! A [`Session`] wraps the existing [`TdLambdaAgent`] over a concrete
//! [`CcnNet`] (the CCN family — columnar, constructive, ccn — is the
//! serveable set; the dense baselines have no snapshot story and are
//! rejected at open). Sessions are created from a [`SessionSpec`],
//! stepped one observation at a time, snapshotted to JSON, restored from
//! a snapshot, and closed.
//!
//! Pure-columnar sessions can also live inside a
//! [`super::batch::ColumnarSessionBatch`]; [`Session::to_lane`] /
//! [`Session::from_lane`] convert between the two representations
//! without loss (both paths step with identical arithmetic).

use crate::config::{build_ccn, LearnerKind};
use crate::learn::{TdConfig, TdLambdaAgent, TdState};
use crate::nets::ccn::CcnNet;
use crate::nets::lstm_column::LstmColumn;
use crate::nets::normalizer::{OnlineNormalizer, NORM_BETA};
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;

use super::batch::{ColumnarBatchSpec, ColumnarLane};

/// Everything needed to open (or re-open) a session.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub learner: LearnerKind,
    pub n_inputs: usize,
    pub td: TdConfig,
    /// normalizer epsilon
    pub eps: f32,
    /// column-initialization seed
    pub seed: u64,
}

impl SessionSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("learner", self.learner.to_json()),
            ("n_inputs", Json::Num(self.n_inputs as f64)),
            ("alpha", Json::Num(self.td.alpha as f64)),
            ("gamma", Json::Num(self.td.gamma as f64)),
            ("lambda", Json::Num(self.td.lambda as f64)),
            ("eps", Json::Num(self.eps as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        Some(Self {
            learner: LearnerKind::from_json(v.get("learner")?)?,
            n_inputs: v.get("n_inputs")?.as_usize()?,
            td: TdConfig {
                alpha: v.get("alpha")?.as_f64()? as f32,
                gamma: v.get("gamma")?.as_f64()? as f32,
                lambda: v.get("lambda")?.as_f64()? as f32,
            },
            eps: v.get("eps")?.as_f64()? as f32,
            seed: v.get("seed")?.as_f64()? as u64,
        })
    }

    /// True when the session is a pure columnar net — the shape the
    /// batched SoA store can hold.
    pub fn batchable(&self) -> Option<ColumnarBatchSpec> {
        match self.learner {
            LearnerKind::Columnar { d } => Some(ColumnarBatchSpec {
                n_inputs: self.n_inputs,
                d,
                td: self.td,
                eps: self.eps,
                beta: NORM_BETA,
            }),
            _ => None,
        }
    }
}

/// One live scalar session.
pub struct Session {
    spec: SessionSpec,
    agent: TdLambdaAgent<CcnNet>,
}

/// Snapshot format version (bumped on breaking changes).
const SNAPSHOT_VERSION: f64 = 1.0;

impl Session {
    /// Open a fresh session. Dense baselines (tbptt/snap1) are refused:
    /// they are benchmark comparators, not serveable CCN-family nets.
    pub fn open(spec: SessionSpec) -> Result<Session, String> {
        if spec.n_inputs == 0 {
            return Err("session: n_inputs must be >= 1".into());
        }
        let net = build_ccn(&spec.learner, spec.n_inputs, spec.eps, spec.seed)
            .map_err(|e| e.to_string())?;
        let agent = TdLambdaAgent::new(net, spec.td);
        Ok(Session { spec, agent })
    }

    pub fn spec(&self) -> &SessionSpec {
        &self.spec
    }

    pub fn steps(&self) -> u64 {
        self.agent.steps()
    }

    /// One online learning step: observation + cumulant in, prediction
    /// made at this step out.
    pub fn step(&mut self, x: &[f32], cumulant: f32) -> Result<f32, String> {
        if x.len() != self.spec.n_inputs {
            return Err(format!(
                "session expects {} inputs, got {}",
                self.spec.n_inputs,
                x.len()
            ));
        }
        Ok(self.agent.step(x, cumulant))
    }

    /// Prediction without learning. The recurrent state still advances
    /// (a prediction *consumes* the observation), but no TD update runs.
    pub fn predict(&mut self, x: &[f32]) -> Result<f32, String> {
        if x.len() != self.spec.n_inputs {
            return Err(format!(
                "session expects {} inputs, got {}",
                self.spec.n_inputs,
                x.len()
            ));
        }
        Ok(self.agent.predict_only(x))
    }

    /// Serialize the complete session (spec + net + TD state). The
    /// snapshot restores to a session that continues bit-identically.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("v", Json::Num(SNAPSHOT_VERSION)),
            ("spec", self.spec.to_json()),
            ("net", self.agent.net.to_json()),
            ("td", self.agent.td_state().to_json()),
        ])
    }

    /// Rebuild a session from [`Self::snapshot`] output.
    pub fn from_snapshot(v: &Json) -> Result<Session, String> {
        let version = v
            .get("v")
            .and_then(|n| n.as_f64())
            .ok_or("snapshot: missing version")?;
        if version != SNAPSHOT_VERSION {
            return Err(format!("snapshot: unsupported version {version}"));
        }
        let spec = v
            .get("spec")
            .and_then(SessionSpec::from_json)
            .ok_or("snapshot: bad spec")?;
        // reject specs we could never have produced (cheap check only;
        // net/spec consistency is validated below and by set_td_state)
        if !spec.learner.is_ccn_family() {
            return Err(format!(
                "snapshot: learner '{}' is not serveable",
                spec.learner.label()
            ));
        }
        let net = CcnNet::from_json(v.get("net").ok_or("snapshot: missing net")?)?;
        if net.config().n_inputs != spec.n_inputs {
            return Err("snapshot: net/spec input width mismatch".into());
        }
        let td = v
            .get("td")
            .and_then(TdState::from_json)
            .ok_or("snapshot: bad td state")?;
        let mut agent = TdLambdaAgent::new(net, spec.td);
        agent.set_td_state(td)?;
        Ok(Session { spec, agent })
    }

    /// Extract this (columnar) session's state as a batch lane. Errors
    /// for non-columnar sessions.
    pub fn to_lane(&self) -> Result<ColumnarLane, String> {
        let d = match self.spec.learner {
            LearnerKind::Columnar { d } => d,
            _ => return Err("only columnar sessions are batchable".into()),
        };
        let net = &self.agent.net;
        let columns: Vec<LstmColumn> =
            (0..d).map(|k| net.column(0, k).clone()).collect();
        let (mu, var, denom) = net.stage_norm(0).state();
        Ok(ColumnarLane {
            columns,
            norm_mu: mu.to_vec(),
            norm_var: var.to_vec(),
            norm_denom: denom.to_vec(),
            td: self.agent.td_state(),
        })
    }

    /// Rebuild a scalar session from a batch lane (inverse of
    /// [`Self::to_lane`]). The columnar net never consumes its rng after
    /// construction, so a fresh stream seeded from the spec is
    /// equivalent to the original.
    pub fn from_lane(spec: SessionSpec, lane: &ColumnarLane) -> Result<Session, String> {
        let batch_spec = spec
            .batchable()
            .ok_or("only columnar sessions are batchable")?;
        let d = batch_spec.d;
        if lane.columns.len() != d {
            return Err(format!(
                "lane has {} columns, spec wants {d}",
                lane.columns.len()
            ));
        }
        let cfg = crate::nets::ccn::CcnConfig {
            n_inputs: spec.n_inputs,
            total_features: d,
            features_per_stage: d,
            steps_per_stage: u64::MAX,
            init_scale: 1.0,
            norm_eps: spec.eps,
            norm_beta: batch_spec.beta,
        };
        let norm = OnlineNormalizer::from_state(
            batch_spec.beta,
            spec.eps,
            lane.norm_mu.clone(),
            lane.norm_var.clone(),
            lane.norm_denom.clone(),
        )
        .ok_or("lane normalizer state inconsistent")?;
        let net = CcnNet::from_parts(
            cfg,
            vec![(lane.columns.clone(), norm)],
            lane.td.steps,
            1,
            false,
            Xoshiro256::seed_from_u64(spec.seed),
        )?;
        let mut agent = TdLambdaAgent::new(net, spec.td);
        agent.set_td_state(lane.td.clone())?;
        Ok(Session { spec, agent })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn columnar_spec() -> SessionSpec {
        SessionSpec {
            learner: LearnerKind::Columnar { d: 4 },
            n_inputs: 3,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            seed: 7,
        }
    }

    fn drive(s: &mut Session, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let x: Vec<f32> = (0..s.spec().n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let c = rng.uniform(-0.5, 0.5);
            ys.push(s.step(&x, c).unwrap());
        }
        ys
    }

    #[test]
    fn open_rejects_dense_baselines_and_zero_inputs() {
        let mut spec = columnar_spec();
        spec.learner = LearnerKind::Tbptt { d: 4, k: 10 };
        assert!(Session::open(spec).is_err());
        let mut spec = columnar_spec();
        spec.n_inputs = 0;
        assert!(Session::open(spec).is_err());
    }

    #[test]
    fn step_checks_observation_width() {
        let mut s = Session::open(columnar_spec()).unwrap();
        assert!(s.step(&[0.0, 0.0], 0.0).is_err());
        assert!(s.step(&[0.0, 0.0, 0.0], 0.0).is_ok());
    }

    #[test]
    fn snapshot_restore_continues_identically() {
        let mut s = Session::open(columnar_spec()).unwrap();
        drive(&mut s, 400, 1);
        let snap = s.snapshot();
        // round-trip through text to exercise the full codec
        let mut restored = Session::from_snapshot(
            &Json::parse(&snap.dump()).unwrap(),
        )
        .unwrap();
        assert_eq!(restored.steps(), s.steps());
        let a = drive(&mut s, 200, 2);
        let b = drive(&mut restored, 200, 2);
        assert_eq!(a, b, "restored session must continue identically");
    }

    #[test]
    fn snapshot_restore_works_for_growing_ccn() {
        let spec = SessionSpec {
            learner: LearnerKind::Ccn {
                total: 6,
                per_stage: 2,
                steps_per_stage: 120,
            },
            n_inputs: 3,
            td: TdConfig::default(),
            eps: 0.01,
            seed: 3,
        };
        let mut s = Session::open(spec).unwrap();
        drive(&mut s, 150, 4); // past one stage boundary
        let snap = s.snapshot();
        let mut restored = Session::from_snapshot(&snap).unwrap();
        // continue across the *next* boundary too: the restored rng must
        // initialize the new stage's columns identically
        let a = drive(&mut s, 200, 5);
        let b = drive(&mut restored, 200, 5);
        assert_eq!(a, b, "growth after restore must match");
    }

    #[test]
    fn lane_roundtrip_continues_identically() {
        let mut s = Session::open(columnar_spec()).unwrap();
        drive(&mut s, 300, 9);
        let lane = s.to_lane().unwrap();
        let mut back = Session::from_lane(s.spec().clone(), &lane).unwrap();
        let a = drive(&mut s, 150, 10);
        let b = drive(&mut back, 150, 10);
        assert_eq!(a, b, "lane extraction must be lossless");
    }

    #[test]
    fn restore_rejects_corrupted_snapshots() {
        let s = Session::open(columnar_spec()).unwrap();
        let snap = s.snapshot();
        // wrong version
        let mut o = match snap.clone() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("v".into(), Json::Num(99.0));
        assert!(Session::from_snapshot(&Json::Obj(o)).is_err());
        // missing net
        let mut o = match snap {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.remove("net");
        assert!(Session::from_snapshot(&Json::Obj(o)).is_err());
    }
}
