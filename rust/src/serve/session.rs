//! Session lifecycle: a single online TD(lambda) learner owned by the
//! prediction service.
//!
//! A [`Session`] wraps the existing [`TdLambdaAgent`] over a boxed
//! [`ServableNet`], so *every* registered net family — `columnar`,
//! `constructive`, `ccn`, `tbptt`, `snap1` — opens, steps, snapshots and
//! restores through the same surface. Snapshots use a versioned envelope:
//!
//! ```json
//! {"v":2, "kind":"tbptt", "spec":{...}, "net":{...}, "td":{...}}
//! ```
//!
//! where `net` is [`PersistableNet::save`] output and restore routes
//! through [`NetRegistry::restore`] by the `kind` tag. Version-1
//! envelopes (PR 1's CCN-only format, no `kind` field) still restore
//! through a migration shim.
//!
//! Sessions whose net reports [`BatchCapability::Columnar`] can also
//! live inside a [`super::batch::ColumnarSessionBatch`];
//! [`Session::to_lane`] / [`Session::from_lane`] convert between the two
//! representations without loss (both paths step with identical
//! arithmetic). Nets reporting [`BatchCapability::Staged`] (ccn and
//! constructive mid-growth) instead convert through
//! [`Session::to_staged_lane`] / [`Session::from_staged_lane`] into
//! stage-keyed [`super::batch::StagedSessionBatch`] cohorts; the
//! `from_staged_lane` path also settles a pending stage boundary —
//! the scalar half of a cohort hop. The capability is *discovered from
//! the net*, never pattern-matched from a learner kind, so future
//! batchable families only need to report their shape.

use crate::config::{build_servable, LearnerKind};
use crate::learn::{TdConfig, TdLambdaAgent, TdState};
use crate::nets::ccn::CcnNet;
use crate::nets::lstm_column::LstmColumn;
use crate::nets::normalizer::OnlineNormalizer;
use crate::nets::{
    BatchCapability, NetRegistry, PersistableNet, PredictionNet, ServableNet,
};
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;

use super::batch::{
    ColumnarBatchSpec, ColumnarLane, StagedBatchSpec, StagedLane, StagedLaneStage,
};

/// Everything needed to open (or re-open) a session.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub learner: LearnerKind,
    pub n_inputs: usize,
    pub td: TdConfig,
    /// normalizer epsilon
    pub eps: f32,
    /// column-initialization seed
    pub seed: u64,
}

impl SessionSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("learner", self.learner.to_json()),
            ("n_inputs", Json::Num(self.n_inputs as f64)),
            ("alpha", Json::Num(self.td.alpha as f64)),
            ("gamma", Json::Num(self.td.gamma as f64)),
            ("lambda", Json::Num(self.td.lambda as f64)),
            ("eps", Json::Num(self.eps as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        Some(Self {
            learner: LearnerKind::from_json(v.get("learner")?)?,
            n_inputs: v.get("n_inputs")?.as_usize()?,
            td: TdConfig {
                alpha: v.get("alpha")?.as_f64()? as f32,
                gamma: v.get("gamma")?.as_f64()? as f32,
                lambda: v.get("lambda")?.as_f64()? as f32,
            },
            eps: v.get("eps")?.as_f64()? as f32,
            seed: v.get("seed")?.as_f64()? as u64,
        })
    }
}

/// One live scalar session.
pub struct Session {
    spec: SessionSpec,
    agent: TdLambdaAgent<Box<dyn ServableNet>>,
}

/// Snapshot envelope version (bumped on breaking changes). v2 added the
/// `kind` tag and registry-routed restore; v1 (CCN family only) restores
/// through a migration shim in [`Session::from_snapshot`].
const SNAPSHOT_VERSION: f64 = 2.0;

impl Session {
    /// Open a fresh session for *any* registered learner kind.
    pub fn open(spec: SessionSpec) -> Result<Session, String> {
        if spec.n_inputs == 0 {
            return Err("session: n_inputs must be >= 1".into());
        }
        let net = build_servable(&spec.learner, spec.n_inputs, spec.eps, spec.seed)
            .map_err(|e| e.to_string())?;
        let agent = TdLambdaAgent::new(net, spec.td);
        Ok(Session { spec, agent })
    }

    pub fn spec(&self) -> &SessionSpec {
        &self.spec
    }

    /// The net's registered snapshot-kind tag.
    pub fn kind(&self) -> &'static str {
        self.agent.net.kind()
    }

    pub fn steps(&self) -> u64 {
        self.agent.steps()
    }

    /// The SoA batch shape this session can live in, discovered from the
    /// net's [`BatchCapability`]; `None` keeps the session scalar.
    pub fn columnar_batch_spec(&self) -> Option<ColumnarBatchSpec> {
        match self.agent.net.batch_capability() {
            BatchCapability::Columnar {
                n_inputs,
                d,
                eps,
                beta,
            } => Some(ColumnarBatchSpec {
                n_inputs,
                d,
                td: self.spec.td,
                eps,
                beta,
            }),
            BatchCapability::None | BatchCapability::Staged { .. } => None,
        }
    }

    /// The stage-keyed cohort shape this session can live in, discovered
    /// from the net's [`BatchCapability::Staged`]; `None` for nets that
    /// are scalar-only or on the columnar fast path.
    pub fn staged_batch_spec(&self) -> Option<StagedBatchSpec> {
        match self.agent.net.batch_capability() {
            BatchCapability::Staged {
                n_inputs,
                stage,
                features_per_stage,
                total_features,
                steps_per_stage,
                init_scale,
                frozen_forever,
                eps,
                beta,
                ..
            } => Some(StagedBatchSpec {
                n_inputs,
                features_per_stage,
                total_features,
                steps_per_stage,
                stage,
                frozen_forever,
                init_scale,
                td: self.spec.td,
                eps,
                beta,
            }),
            BatchCapability::None | BatchCapability::Columnar { .. } => None,
        }
    }

    /// One online learning step: observation + cumulant in, prediction
    /// made at this step out.
    pub fn step(&mut self, x: &[f32], cumulant: f32) -> Result<f32, String> {
        if x.len() != self.spec.n_inputs {
            return Err(format!(
                "session expects {} inputs, got {}",
                self.spec.n_inputs,
                x.len()
            ));
        }
        Ok(self.agent.step(x, cumulant))
    }

    /// Prediction without learning. The recurrent state still advances
    /// (a prediction *consumes* the observation), but no TD update runs.
    pub fn predict(&mut self, x: &[f32]) -> Result<f32, String> {
        if x.len() != self.spec.n_inputs {
            return Err(format!(
                "session expects {} inputs, got {}",
                self.spec.n_inputs,
                x.len()
            ));
        }
        Ok(self.agent.predict_only(x))
    }

    /// Serialize the complete session (spec + net + TD state) into the
    /// v2 envelope. The snapshot restores to a session that continues
    /// bit-identically.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("v", Json::Num(SNAPSHOT_VERSION)),
            ("kind", Json::Str(self.kind().into())),
            ("spec", self.spec.to_json()),
            ("net", self.agent.net.save()),
            ("td", self.agent.td_state().to_json()),
        ])
    }

    /// Rebuild a session from [`Self::snapshot`] output (v2) or from a
    /// PR-1 v1 CCN snapshot (migration shim).
    pub fn from_snapshot(v: &Json) -> Result<Session, String> {
        let version = v
            .get("v")
            .and_then(|n| n.as_f64())
            .ok_or("snapshot: missing version")?;
        let spec = v
            .get("spec")
            .and_then(SessionSpec::from_json)
            .ok_or("snapshot: bad spec")?;
        let net_json = v.get("net").ok_or("snapshot: missing net")?;
        let net: Box<dyn ServableNet> = if version == 1.0 {
            // v1 envelopes carried no `kind` and covered the CCN family
            // only; their `net` payload is exactly CcnNet::from_json's
            // input, so migration is a direct restore.
            if !spec.learner.is_ccn_family() {
                return Err(format!(
                    "snapshot: v1 envelopes cover the CCN family only, \
                     spec says '{}'",
                    spec.learner.label()
                ));
            }
            Box::new(CcnNet::from_json(net_json)?)
        } else if version == SNAPSHOT_VERSION {
            let kind = v
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or("snapshot: missing kind")?;
            // the envelope kind must serialize-compatibly match the spec:
            // same registry family (the CCN corners share one format).
            let spec_family = NetRegistry::family(spec.learner.kind())
                .ok_or("snapshot: spec learner is not registered")?;
            if NetRegistry::family(kind) != Some(spec_family) {
                return Err(format!(
                    "snapshot: kind '{kind}' does not match spec learner '{}'",
                    spec.learner.label()
                ));
            }
            NetRegistry::restore(kind, net_json)?
        } else {
            return Err(format!("snapshot: unsupported version {version}"));
        };
        if net.n_inputs() != spec.n_inputs {
            return Err("snapshot: net/spec input width mismatch".into());
        }
        let td = v
            .get("td")
            .and_then(TdState::from_json)
            .ok_or("snapshot: bad td state")?;
        let mut agent = TdLambdaAgent::new(net, spec.td);
        agent.set_td_state(td)?;
        Ok(Session { spec, agent })
    }

    /// Extract this session's state as a batch lane. Errors for sessions
    /// without [`BatchCapability::Columnar`]. The [`ColumnarLane`]
    /// interchange format is stride-independent: the batch writes it
    /// into (and reads it out of) its capacity-padded arrays without the
    /// scalar side ever seeing the padding.
    pub fn to_lane(&self) -> Result<ColumnarLane, String> {
        let d = match self.agent.net.batch_capability() {
            BatchCapability::Columnar { d, .. } => d,
            BatchCapability::None | BatchCapability::Staged { .. } => {
                return Err("session's net reports no columnar batch capability".into())
            }
        };
        let net = self
            .agent
            .net
            .as_any()
            .downcast_ref::<CcnNet>()
            .ok_or("columnar batch capability implies a CCN-family net")?;
        let columns: Vec<LstmColumn> =
            (0..d).map(|k| net.column(0, k).clone()).collect();
        let (mu, var, denom) = net.stage_norm(0).state();
        Ok(ColumnarLane {
            columns,
            norm_mu: mu.to_vec(),
            norm_var: var.to_vec(),
            norm_denom: denom.to_vec(),
            td: self.agent.td_state(),
        })
    }

    /// Rebuild a scalar session from a batch lane (inverse of
    /// [`Self::to_lane`]; `batch_spec` is the shape of the batch the lane
    /// lived in). The columnar net never consumes its rng after
    /// construction, so a fresh stream seeded from the spec is equivalent
    /// to the original.
    pub fn from_lane(
        spec: SessionSpec,
        batch_spec: &ColumnarBatchSpec,
        lane: &ColumnarLane,
    ) -> Result<Session, String> {
        let d = batch_spec.d;
        if lane.columns.len() != d {
            return Err(format!(
                "lane has {} columns, batch wants {d}",
                lane.columns.len()
            ));
        }
        let cfg = crate::nets::ccn::CcnConfig {
            n_inputs: batch_spec.n_inputs,
            total_features: d,
            features_per_stage: d,
            steps_per_stage: u64::MAX,
            init_scale: 1.0,
            norm_eps: batch_spec.eps,
            norm_beta: batch_spec.beta,
        };
        let norm = OnlineNormalizer::from_state(
            batch_spec.beta,
            batch_spec.eps,
            lane.norm_mu.clone(),
            lane.norm_var.clone(),
            lane.norm_denom.clone(),
        )
        .ok_or("lane normalizer state inconsistent")?;
        let net = CcnNet::from_parts(
            cfg,
            vec![(lane.columns.clone(), norm)],
            lane.td.steps,
            1,
            false,
            Xoshiro256::seed_from_u64(spec.seed),
        )?;
        let mut agent =
            TdLambdaAgent::new(Box::new(net) as Box<dyn ServableNet>, spec.td);
        agent.set_td_state(lane.td.clone())?;
        Ok(Session { spec, agent })
    }

    /// Extract this session's state as a staged-cohort lane. Errors for
    /// sessions without [`BatchCapability::Staged`]. Unlike the columnar
    /// lane, a staged lane carries every materialized stage, the stage
    /// clock and the live rng state (the next cohort hop consumes it to
    /// mint the new stage's columns exactly as the scalar net would).
    pub fn to_staged_lane(&self) -> Result<StagedLane, String> {
        match self.agent.net.batch_capability() {
            BatchCapability::Staged { .. } => {}
            BatchCapability::None | BatchCapability::Columnar { .. } => {
                return Err("session's net reports no staged batch capability".into())
            }
        }
        let net = self
            .agent
            .net
            .as_any()
            .downcast_ref::<CcnNet>()
            .ok_or("staged batch capability implies a CCN-family net")?;
        let stages = (0..net.n_stages())
            .map(|s| {
                let (mu, var, denom) = net.stage_norm(s).state();
                StagedLaneStage {
                    columns: (0..mu.len()).map(|k| net.column(s, k).clone()).collect(),
                    norm_mu: mu.to_vec(),
                    norm_var: var.to_vec(),
                    norm_denom: denom.to_vec(),
                }
            })
            .collect();
        Ok(StagedLane {
            stages,
            steps_in_stage: net.steps_in_stage(),
            rng: net.rng_state(),
            td: self.agent.td_state(),
        })
    }

    /// Rebuild a scalar session from a staged-cohort lane (inverse of
    /// [`Self::to_staged_lane`]). If the lane's stage clock crossed the
    /// boundary (the cohort reported it *pending*), this settles the
    /// transition exactly as the scalar net would have inside its
    /// crossing step: the rng carried in the lane mints the next stage's
    /// columns, and the TD state is zero-extended the way the agent's
    /// growth sync does — so hop-then-continue is bit-identical to a
    /// never-batched session.
    pub fn from_staged_lane(
        spec: SessionSpec,
        batch_spec: &StagedBatchSpec,
        lane: &StagedLane,
    ) -> Result<Session, String> {
        let cfg = crate::nets::ccn::CcnConfig {
            n_inputs: batch_spec.n_inputs,
            total_features: batch_spec.total_features,
            features_per_stage: batch_spec.features_per_stage,
            steps_per_stage: batch_spec.steps_per_stage,
            init_scale: batch_spec.init_scale,
            norm_eps: batch_spec.eps,
            norm_beta: batch_spec.beta,
        };
        let parts = lane
            .stages
            .iter()
            .map(|st| {
                let norm = OnlineNormalizer::from_state(
                    batch_spec.beta,
                    batch_spec.eps,
                    st.norm_mu.clone(),
                    st.norm_var.clone(),
                    st.norm_denom.clone(),
                )
                .ok_or("staged lane normalizer state inconsistent")?;
                Ok((st.columns.clone(), norm))
            })
            .collect::<Result<Vec<_>, String>>()?;
        let mut net = CcnNet::from_parts(
            cfg,
            parts,
            lane.steps_in_stage,
            lane.td.epoch_seen,
            batch_spec.frozen_forever,
            Xoshiro256::from_state(lane.rng),
        )?;
        let mut td = lane.td.clone();
        if !batch_spec.frozen_forever
            && lane.steps_in_stage >= batch_spec.steps_per_stage
        {
            net.settle_stage_boundary();
            let d = net.n_features();
            td.w.resize(d, 0.0);
            td.e_w.resize(d, 0.0);
            td.e_theta = vec![0.0; net.n_learnable_params()];
            td.epoch_seen = net.param_epoch();
        }
        let mut agent =
            TdLambdaAgent::new(Box::new(net) as Box<dyn ServableNet>, spec.td);
        agent.set_td_state(td)?;
        Ok(Session { spec, agent })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn columnar_spec() -> SessionSpec {
        SessionSpec {
            learner: LearnerKind::Columnar { d: 4 },
            n_inputs: 3,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            seed: 7,
        }
    }

    fn spec_for(learner: LearnerKind) -> SessionSpec {
        SessionSpec {
            learner,
            ..columnar_spec()
        }
    }

    fn drive(s: &mut Session, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let x: Vec<f32> = (0..s.spec().n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let c = rng.uniform(-0.5, 0.5);
            ys.push(s.step(&x, c).unwrap());
        }
        ys
    }

    #[test]
    fn open_accepts_every_registered_kind() {
        for learner in [
            LearnerKind::Columnar { d: 4 },
            LearnerKind::Constructive {
                total: 4,
                steps_per_stage: 50,
            },
            LearnerKind::Ccn {
                total: 4,
                per_stage: 2,
                steps_per_stage: 50,
            },
            LearnerKind::Tbptt { d: 3, k: 6 },
            LearnerKind::Snap1 { d: 3 },
        ] {
            let kind = learner.kind();
            let mut s = Session::open(spec_for(learner)).unwrap();
            assert_eq!(s.kind(), kind);
            assert!(s.step(&[0.1, 0.2, 0.3], 0.0).unwrap().is_finite());
        }
    }

    #[test]
    fn open_rejects_zero_inputs() {
        let mut spec = columnar_spec();
        spec.n_inputs = 0;
        assert!(Session::open(spec).is_err());
    }

    #[test]
    fn step_checks_observation_width() {
        let mut s = Session::open(columnar_spec()).unwrap();
        assert!(s.step(&[0.0, 0.0], 0.0).is_err());
        assert!(s.step(&[0.0, 0.0, 0.0], 0.0).is_ok());
    }

    #[test]
    fn batch_capability_routes_each_family() {
        // the columnar corner batches columnar, never staged
        let s = Session::open(columnar_spec()).unwrap();
        assert!(s.columnar_batch_spec().is_some());
        assert!(s.staged_batch_spec().is_none());
        assert!(s.to_staged_lane().is_err());
        // growing ccn/constructive batch as stage-keyed cohorts
        for learner in [
            LearnerKind::Ccn {
                total: 4,
                per_stage: 2,
                steps_per_stage: 50,
            },
            LearnerKind::Constructive {
                total: 3,
                steps_per_stage: 50,
            },
        ] {
            let s = Session::open(spec_for(learner)).unwrap();
            assert!(s.columnar_batch_spec().is_none(), "{}", s.kind());
            assert!(s.to_lane().is_err());
            let bs = s.staged_batch_spec().unwrap_or_else(|| {
                panic!("{} must report a staged cohort shape", s.kind())
            });
            assert_eq!(bs.stage, 0);
            assert!(!bs.frozen_forever);
            assert!(s.to_staged_lane().is_ok());
        }
        // dense baselines stay scalar on every path
        for learner in [LearnerKind::Tbptt { d: 2, k: 4 }, LearnerKind::Snap1 { d: 2 }] {
            let s = Session::open(spec_for(learner)).unwrap();
            assert!(s.columnar_batch_spec().is_none(), "{}", s.kind());
            assert!(s.staged_batch_spec().is_none(), "{}", s.kind());
            assert!(s.to_lane().is_err());
            assert!(s.to_staged_lane().is_err());
        }
    }

    #[test]
    fn snapshot_restore_continues_identically_for_every_kind() {
        for learner in [
            LearnerKind::Columnar { d: 4 },
            LearnerKind::Constructive {
                total: 4,
                steps_per_stage: 120,
            },
            LearnerKind::Tbptt { d: 3, k: 7 },
            LearnerKind::Snap1 { d: 3 },
        ] {
            let mut s = Session::open(spec_for(learner)).unwrap();
            drive(&mut s, 400, 1);
            let snap = s.snapshot();
            assert_eq!(snap.get("v"), Some(&Json::Num(2.0)));
            assert_eq!(
                snap.get("kind").and_then(|k| k.as_str()),
                Some(s.kind())
            );
            // round-trip through text to exercise the full codec
            let mut restored =
                Session::from_snapshot(&Json::parse(&snap.dump()).unwrap())
                    .unwrap_or_else(|e| panic!("{}: {e}", s.kind()));
            assert_eq!(restored.steps(), s.steps());
            assert_eq!(restored.kind(), s.kind());
            let a = drive(&mut s, 200, 2);
            let b = drive(&mut restored, 200, 2);
            assert_eq!(a, b, "{} must continue identically", s.kind());
        }
    }

    #[test]
    fn snapshot_restore_works_for_growing_ccn() {
        let spec = SessionSpec {
            learner: LearnerKind::Ccn {
                total: 6,
                per_stage: 2,
                steps_per_stage: 120,
            },
            n_inputs: 3,
            td: TdConfig::default(),
            eps: 0.01,
            seed: 3,
        };
        let mut s = Session::open(spec).unwrap();
        drive(&mut s, 150, 4); // past one stage boundary
        let snap = s.snapshot();
        let mut restored = Session::from_snapshot(&snap).unwrap();
        // continue across the *next* boundary too: the restored rng must
        // initialize the new stage's columns identically
        let a = drive(&mut s, 200, 5);
        let b = drive(&mut restored, 200, 5);
        assert_eq!(a, b, "growth after restore must match");
    }

    #[test]
    fn v1_ccn_snapshot_restores_through_migration_shim() {
        let mut s = Session::open(columnar_spec()).unwrap();
        drive(&mut s, 300, 8);
        // rewrite the v2 envelope into the exact shape PR 1 wrote:
        // {"v":1,"spec","net","td"} with no "kind" field.
        let mut o = match s.snapshot() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("v".into(), Json::Num(1.0));
        o.remove("kind");
        let mut restored = Session::from_snapshot(&Json::Obj(o)).unwrap();
        let a = drive(&mut s, 100, 9);
        let b = drive(&mut restored, 100, 9);
        assert_eq!(a, b, "v1 shim must restore losslessly");
    }

    #[test]
    fn v1_shim_rejects_dense_baselines() {
        let mut s = Session::open(spec_for(LearnerKind::Tbptt { d: 2, k: 4 }))
            .unwrap();
        drive(&mut s, 20, 1);
        let mut o = match s.snapshot() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("v".into(), Json::Num(1.0));
        o.remove("kind");
        let err = Session::from_snapshot(&Json::Obj(o)).unwrap_err();
        assert!(err.contains("v1"), "{err}");
    }

    #[test]
    fn lane_roundtrip_continues_identically() {
        let mut s = Session::open(columnar_spec()).unwrap();
        drive(&mut s, 300, 9);
        let batch_spec = s.columnar_batch_spec().unwrap();
        let lane = s.to_lane().unwrap();
        let mut back =
            Session::from_lane(s.spec().clone(), &batch_spec, &lane).unwrap();
        let a = drive(&mut s, 150, 10);
        let b = drive(&mut back, 150, 10);
        assert_eq!(a, b, "lane extraction must be lossless");
    }

    #[test]
    fn staged_lane_roundtrip_continues_identically() {
        let spec = SessionSpec {
            learner: LearnerKind::Ccn {
                total: 6,
                per_stage: 2,
                steps_per_stage: 120,
            },
            ..columnar_spec()
        };
        let mut s = Session::open(spec).unwrap();
        drive(&mut s, 150, 9); // past one boundary: stage 1 learning
        let batch_spec = s.staged_batch_spec().unwrap();
        assert_eq!(batch_spec.stage, 1);
        let lane = s.to_staged_lane().unwrap();
        let mut back =
            Session::from_staged_lane(s.spec().clone(), &batch_spec, &lane).unwrap();
        // continue across the *next* boundary too: the rng state carried
        // in the lane must mint identical stage-2 columns
        let a = drive(&mut s, 200, 10);
        let b = drive(&mut back, 200, 10);
        assert_eq!(a, b, "staged lane extraction must be lossless");
    }

    #[test]
    fn staged_lane_pending_hop_matches_scalar_crossing() {
        use crate::serve::batch::StagedSessionBatch;

        let spec = SessionSpec {
            learner: LearnerKind::Ccn {
                total: 4,
                per_stage: 2,
                steps_per_stage: 30,
            },
            ..columnar_spec()
        };
        let mut twin = Session::open(spec.clone()).unwrap();
        let mut src = Session::open(spec.clone()).unwrap();
        drive(&mut twin, 29, 12);
        drive(&mut src, 29, 12);
        let batch_spec = src.staged_batch_spec().unwrap();
        let mut batch = StagedSessionBatch::from_lanes(
            batch_spec.clone(),
            &[src.to_staged_lane().unwrap()],
        )
        .unwrap();
        // the crossing step: the scalar net settles the boundary in-net
        // (after its TD update), the cohort reports the lane pending —
        // the step's predictions still agree
        let mut rng = Xoshiro256::seed_from_u64(13);
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let c = rng.uniform(-0.5, 0.5);
        let y_batch = batch.step_one(0, &x, c);
        assert_eq!(y_batch, twin.step(&x, c).unwrap());
        assert!(batch.lane_pending(0));
        // hop: extract, settle, continue — bit-identical to the twin
        let lane = batch.swap_remove_lane(0).unwrap();
        let mut hopped =
            Session::from_staged_lane(spec.clone(), &batch_spec, &lane).unwrap();
        assert_eq!(hopped.staged_batch_spec().unwrap().stage, 1);
        let a = drive(&mut hopped, 100, 14);
        let b = drive(&mut twin, 100, 14);
        assert_eq!(a, b, "cohort hop must match the scalar stage transition");
    }

    #[test]
    fn restore_rejects_corrupted_snapshots() {
        let s = Session::open(columnar_spec()).unwrap();
        let snap = s.snapshot();
        // wrong version
        let mut o = match snap.clone() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("v".into(), Json::Num(99.0));
        assert!(Session::from_snapshot(&Json::Obj(o)).is_err());
        // missing net
        let mut o = match snap.clone() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.remove("net");
        assert!(Session::from_snapshot(&Json::Obj(o)).is_err());
        // kind from a different family than the spec
        let mut o = match snap {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("kind".into(), Json::Str("tbptt".into()));
        let err = Session::from_snapshot(&Json::Obj(o)).unwrap_err();
        assert!(err.contains("does not match"), "{err}");
    }
}
