//! Sharded session ownership: N worker threads, each owning a disjoint
//! set of sessions behind an mpsc queue.
//!
//! Sessions are routed by `id % n_shards`, so a session's state is only
//! ever touched by its owning shard — the hot path takes no locks.
//! Within a shard, sessions whose net reports
//! [`crate::nets::BatchCapability::Columnar`] live in SoA
//! [`ColumnarSessionBatch`]es keyed by their shape, and sessions
//! reporting [`crate::nets::BatchCapability::Staged`] (growing
//! ccn/constructive nets) live in stage-keyed [`StagedSessionBatch`]
//! cohorts — the batch key is (spec shape, learning-stage index), so
//! every member is structurally identical. A `StepMany` request that
//! covers a whole batch advances it in one fused pass. When a staged
//! session's stage clock crosses `steps_per_stage` it *hops* cohorts:
//! the lane is swap-removed, the boundary settles (freeze the learning
//! stage, spawn the next from the lane rng), and placement re-discovers
//! capability, landing it in the next stage's cohort. Everything else
//! (dense baselines, partial batches) takes the scalar path. All paths
//! produce identical numbers — membership is a performance decision,
//! never a semantic one.
//!
//! # The durable tier
//!
//! With a [`StoreConfig`] mounted, each shard also owns a
//! [`SessionStore`] under `<dir>/shard-<k>/` and keeps at most
//! `resident_cap` sessions in memory. Every session-addressed op touches
//! an LRU; crossing the cap evicts the coldest session (snapshot ->
//! [`SessionStore::park`] -> drop the slot, including its SoA batch
//! lane). Ops addressed to a parked id transparently rehydrate it (load
//! -> [`Session::from_snapshot`], which routes the envelope's kind tag
//! through [`crate::nets::NetRegistry`]). Eviction and rehydration reuse
//! the snapshot codec, so a session that bounced through disk continues
//! bit-identically — membership in memory, like membership in a batch,
//! is never a semantic decision.
//!
//! Batch membership ops ride the capacity-padded SoA layout
//! ([`super::batch`]): inserting a rehydrated session writes one lane in
//! place and evicting one swap-removes one lane — both O(that session's
//! state), so churn under `--resident-cap` costs the same against a
//! 256-session batch as against a 16-session one. Sparse batches are
//! compacted on the removal path (<= 1/4 occupancy), never per op.
//!
//! [`ShardPool::close`] drains every shard (flushing resident sessions to
//! the store) and joins the workers deterministically; dropping the pool
//! without closing joins the workers but skips the flush, which is
//! exactly a crash as far as the store is concerned — only parked state
//! survives, and boot-time recovery resumes it.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::nets::NetRegistry;
use crate::obs::{Histogram, Registry, StageCell};
use crate::store::{IdWatermark, SessionStore, StoreConfig};
use crate::util::fault::{self, FaultAction};
use crate::util::json::Json;

use super::batch::{
    ColumnarBatchSpec, ColumnarSessionBatch, StagedBatchSpec, StagedSessionBatch,
};
use super::protocol::{Request, Response, ShardStats, StepItem};
use super::session::{Session, SessionSpec};

/// Message prefix tagging errors that originate in the durable store
/// tier. [`error_of`] lifts the tag into the wire-level `retriable`
/// flag: a store failure is a property of this backend's disk, not of
/// the op itself, so a router holding a replica elsewhere may retry
/// against a promoted standby. The prefix stays in the message — logs
/// should say where the error came from.
pub(crate) const STORE_ERR: &str = "store-tier: ";

/// Lift a plumbing error into a wire [`Response`], marking store-tier
/// failures (see [`STORE_ERR`]) retriable; everything else keeps the
/// terminal (non-retriable) default.
fn error_of(e: String) -> Response {
    if e.starts_with(STORE_ERR) {
        Response::error_retriable(e)
    } else {
        Response::error(e)
    }
}

/// Run one store-tier operation through its fault-injection point and
/// tag any failure with [`STORE_ERR`]. An injected `Drop` or `Truncate`
/// becomes a synthetic error (a lost or half-written record reads back
/// as a failure either way); `Delay` stalls, then runs the real op;
/// `Dup` is meaningless for idempotent store ops and runs once.
fn store_op<T>(
    point: &str,
    op: impl FnOnce() -> Result<T, String>,
) -> Result<T, String> {
    match fault::hit(point) {
        Some(FaultAction::Drop) | Some(FaultAction::Truncate) => {
            return Err(format!("{STORE_ERR}injected {point} fault"));
        }
        Some(FaultAction::Delay(ms)) => fault::sleep_ms(ms),
        Some(FaultAction::Dup) | None => {}
    }
    op().map_err(|e| format!("{STORE_ERR}{e}"))
}

/// Hashable key for "sessions with this shape can share a batch":
/// (n_inputs, d, alpha, gamma, lambda, eps, beta) with floats by bit
/// pattern. Every shape-defining field of [`ColumnarBatchSpec`] must
/// appear here — beta included, since a restored snapshot may carry a
/// non-default normalizer beta.
type BatchKey = (usize, usize, u32, u32, u32, u32, u32);

fn batch_key(spec: &ColumnarBatchSpec) -> BatchKey {
    (
        spec.n_inputs,
        spec.d,
        spec.td.alpha.to_bits(),
        spec.td.gamma.to_bits(),
        spec.td.lambda.to_bits(),
        spec.eps.to_bits(),
        spec.beta.to_bits(),
    )
}

/// Hashable cohort key for "sessions at this shape *and this learning
/// stage* can share a [`StagedSessionBatch`]": every shape-defining
/// field of [`StagedBatchSpec`] appears, floats by bit pattern. Two
/// sessions with equal keys have byte-compatible frozen prefixes and
/// identical learning-stage geometry — `prefix_sig` alone would be a
/// hash that *could* collide, so the full shape is spelled out instead.
type StagedKey = (usize, usize, usize, u64, usize, bool, [u32; 6]);

fn staged_key(spec: &StagedBatchSpec) -> StagedKey {
    (
        spec.n_inputs,
        spec.features_per_stage,
        spec.total_features,
        spec.steps_per_stage,
        spec.stage,
        spec.frozen_forever,
        [
            spec.init_scale.to_bits(),
            spec.eps.to_bits(),
            spec.beta.to_bits(),
            spec.td.alpha.to_bits(),
            spec.td.gamma.to_bits(),
            spec.td.lambda.to_bits(),
        ],
    )
}

/// Where a session's state lives inside a shard.
enum Slot {
    Scalar(Box<Session>),
    /// `(batch key, lane index)` — the spec is kept for snapshots.
    Batched(BatchKey, usize, SessionSpec),
    /// `(cohort key, lane index)` in a stage-keyed cohort — a growing
    /// ccn/constructive session batched with cohort-mates at the same
    /// learning stage; the spec is kept for snapshots.
    Staged(StagedKey, usize, SessionSpec),
}

/// Pre-resolved telemetry handles for one shard's hot-path stages.
/// Resolved once from the pool registry at worker spawn, so recording
/// never touches the registry lock. Measurement-only: nothing here
/// influences routing, stepping, or persistence.
#[derive(Clone)]
pub struct ShardObs {
    registry: Arc<Registry>,
    queue_wait: Arc<Histogram>,
    step_scalar: Arc<Histogram>,
    step_batched: Arc<Histogram>,
    store_append: Arc<Histogram>,
    store_load: Arc<Histogram>,
    store_compact: Arc<Histogram>,
}

impl ShardObs {
    pub fn new(registry: Arc<Registry>) -> ShardObs {
        ShardObs {
            queue_wait: registry.histogram("stage.queue_wait"),
            step_scalar: registry.histogram("stage.step_scalar"),
            step_batched: registry.histogram("stage.step_batched"),
            store_append: registry.histogram("stage.store_append"),
            store_load: registry.histogram("stage.store_load"),
            store_compact: registry.histogram("stage.store_compact"),
            registry,
        }
    }

    /// Handles backed by a private registry nobody exports — lets
    /// `ShardState` keep an infallible `Default` for direct (test/bench)
    /// construction without an `Option` on every record site.
    fn detached() -> ShardObs {
        ShardObs::new(Arc::new(Registry::new()))
    }

    fn kind_counter(&self, kind: &str) -> Arc<AtomicU64> {
        self.registry.counter(&format!("steps.{kind}"))
    }
}

impl Default for ShardObs {
    fn default() -> ShardObs {
        ShardObs::detached()
    }
}

/// Single-threaded session owner; one per worker thread.
#[derive(Default)]
pub struct ShardState {
    slots: HashMap<u64, Slot>,
    batches: HashMap<BatchKey, ColumnarSessionBatch>,
    /// lane index -> session id, per batch (to re-key on swap-remove and
    /// to detect full-batch coverage)
    lane_ids: HashMap<BatchKey, Vec<u64>>,
    /// stage-keyed cohorts: ccn/constructive sessions at the same spec
    /// *and the same learning stage* share one SoA batch, and hop to
    /// the next cohort when their stage clock crosses `steps_per_stage`
    staged_batches: HashMap<StagedKey, StagedSessionBatch>,
    /// lane index -> session id, per staged cohort
    staged_lane_ids: HashMap<StagedKey, Vec<u64>>,
    steps_served: u64,
    /// durable tier (None = everything stays resident forever)
    store: Option<SessionStore>,
    /// max resident sessions before LRU eviction; 0 = unlimited
    resident_cap: usize,
    /// LRU bookkeeping: a monotone clock, id -> last-touch tick, and the
    /// inverse (tick -> id, ticks are unique) for O(log n) victim picks
    clock: u64,
    last_used: HashMap<u64, u64>,
    lru: BTreeMap<u64, u64>,
    /// resident sessions whose state is newer than their parked copy
    dirty: HashSet<u64>,
    evictions: u64,
    rehydrations: u64,
    /// stage timers + per-kind step counters (detached unless a pool
    /// wires in its shared registry via [`ShardState::set_obs`])
    obs: ShardObs,
    /// cached `steps.<kind>` counter handles, keyed by the `'static`
    /// kind tag so the hot path never formats a name
    kind_steps: HashMap<&'static str, Arc<AtomicU64>>,
    /// store + kernel nanoseconds spent inside the *current* request;
    /// reset at `handle()` entry, read by the worker for trace events
    scratch_store_ns: u64,
    scratch_kernel_ns: u64,
}

impl ShardState {
    pub fn new() -> Self {
        Self::default()
    }

    /// A shard with the durable tier mounted.
    pub fn with_store(store: Option<SessionStore>, resident_cap: usize) -> Self {
        Self {
            store,
            resident_cap,
            ..Self::default()
        }
    }

    /// Resident session count (parked sessions live in the store).
    pub fn n_sessions(&self) -> usize {
        self.slots.len()
    }

    /// Wire this shard into a shared telemetry registry (stage timers,
    /// per-kind step counters, store compaction latency).
    pub fn set_obs(&mut self, obs: ShardObs) {
        if let Some(store) = self.store.as_mut() {
            store.set_compact_observer(Arc::clone(&obs.store_compact));
        }
        self.obs = obs;
        // handles cached against the old registry are stale
        self.kind_steps.clear();
    }

    fn bump_kind_steps(&mut self, kind: &'static str, n: u64) {
        if !self.kind_steps.contains_key(kind) {
            let counter = self.obs.kind_counter(kind);
            self.kind_steps.insert(kind, counter);
        }
        self.kind_steps[kind].fetch_add(n, Ordering::Relaxed);
    }

    /// Mark `id` most-recently-used.
    fn touch(&mut self, id: u64) {
        self.clock += 1;
        if let Some(old) = self.last_used.insert(id, self.clock) {
            self.lru.remove(&old);
        }
        self.lru.insert(self.clock, id);
    }

    /// Forget LRU/dirty bookkeeping for a session leaving residency.
    fn untrack(&mut self, id: u64) {
        if let Some(clk) = self.last_used.remove(&id) {
            self.lru.remove(&clk);
        }
        self.dirty.remove(&id);
    }

    /// Execute one request against this shard's sessions.
    pub fn handle(&mut self, req: Request) -> Response {
        // per-request stage scratch: the worker reads these after the
        // dispatch below to fill a sampled trace event's breakdown
        self.scratch_store_ns = 0;
        self.scratch_kernel_ns = 0;
        match req {
            Request::Open { id, spec } => self.open(id, spec),
            Request::Step { id, x, c } => match self.step_session(id, &x, c) {
                Ok(y) => Response::Stepped { y },
                Err(e) => error_of(e),
            },
            Request::StepMany { items } => Response::SteppedMany {
                ys: self.step_many(items),
            },
            Request::Predict { id, x } => match self.predict_session(id, &x) {
                Ok(y) => Response::Predicted { y },
                Err(e) => error_of(e),
            },
            Request::Snapshot { id } => match self.snapshot_session(id) {
                Ok(state) => Response::Snapshotted { state },
                Err(e) => error_of(e),
            },
            Request::Restore { id, state } => self.restore_session(id, &state),
            Request::Park { id } => self.park(id),
            Request::Warm { id } => match self.ensure_resident(id) {
                Ok(rehydrated) => Response::Warmed { id, rehydrated },
                Err(e) => error_of(e),
            },
            Request::Replicate { id, state } => self.replicate(id, &state),
            Request::Close { id } => self.close(id),
            Request::Stats => Response::Stats(self.stats()),
            Request::Drain => self.drain(),
        }
    }

    fn stats(&self) -> ShardStats {
        let parked = self.store.as_ref().map_or(0, |s| {
            s.ids()
                .into_iter()
                .filter(|id| !self.slots.contains_key(id))
                .count()
        });
        ShardStats {
            sessions: self.slots.len() + parked,
            steps: self.steps_served,
            kinds: self.kind_counts(),
            cohorts: self.cohort_counts(),
            resident: self.slots.len(),
            parked,
            store_bytes: self.store.as_ref().map_or(0, |s| s.bytes()),
            evictions: self.evictions,
            rehydrations: self.rehydrations,
        }
    }

    /// `restore` admits a wire snapshot at `id`. When a session with
    /// that id already exists — resident or parked — the snapshot
    /// *replaces* it, and because placement re-discovers
    /// [`crate::nets::BatchCapability`] from the restored net, a restore
    /// that flips the capability corner (a columnar envelope landing on
    /// an id that held a dense tbptt session, a ccn envelope replacing a
    /// columnar one, or vice versa) migrates the session between scalar
    /// and batched residency instead of stranding a stale lane around a
    /// net it no longer matches.
    fn restore_session(&mut self, id: u64, state: &Json) -> Response {
        // decode before destroying anything: a malformed envelope must
        // leave the existing session untouched
        let session = match Session::from_snapshot(state) {
            Ok(s) => s,
            Err(e) => return Response::error(e),
        };
        if self.slots.contains_key(&id) {
            if let Err(e) = self.drop_slot(id) {
                return Response::error(e);
            }
        }
        if let Some(store) = self.store.as_mut() {
            if store.contains(id) {
                if let Err(e) = store.delete(id) {
                    return error_of(format!("{STORE_ERR}{e}"));
                }
            }
        }
        self.insert(id, session)
    }

    /// Make `id` resident: a no-op touch when it already is, a store
    /// load + registry-routed restore when it is parked. Returns whether
    /// a rehydration happened.
    fn ensure_resident(&mut self, id: u64) -> Result<bool, String> {
        if self.slots.contains_key(&id) {
            self.touch(id);
            return Ok(false);
        }
        let parked = self.store.as_ref().is_some_and(|s| s.contains(id));
        if !parked {
            return Err(format!("no session {id}"));
        }
        let t = Instant::now();
        let envelope = store_op("store.load", || {
            self.store.as_ref().expect("store present").load(id)
        })?;
        let dt = t.elapsed();
        self.obs.store_load.record_duration(dt);
        self.scratch_store_ns += dt.as_nanos() as u64;
        let session = Session::from_snapshot(&envelope)
            .map_err(|e| format!("rehydrate session {id}: {e}"))?;
        self.place(id, session)?;
        self.rehydrations += 1;
        self.touch(id);
        // freshly rehydrated state equals the disk copy
        self.dirty.remove(&id);
        self.evict_to_cap()?;
        Ok(true)
    }

    /// Evict least-recently-used sessions until the resident count is
    /// back under the cap. Touch the session you are serving *before*
    /// calling this.
    fn evict_to_cap(&mut self) -> Result<(), String> {
        if self.resident_cap == 0 || self.store.is_none() {
            return Ok(());
        }
        while self.slots.len() > self.resident_cap {
            let victim = match self.lru.iter().next() {
                Some((_, &id)) => id,
                None => break,
            };
            self.park_out(victim)?;
            self.evictions += 1;
        }
        Ok(())
    }

    /// Snapshot -> park -> drop the resident slot. The snapshot is
    /// written (and synced) *before* the slot is removed, so a store
    /// failure leaves the session resident rather than lost. Clean
    /// sessions (parked copy already current) skip the write.
    fn park_out(&mut self, id: u64) -> Result<(), String> {
        if self.store.is_none() {
            return Err("no store configured (start serve with --store-dir)".into());
        }
        let current_on_disk = !self.dirty.contains(&id)
            && self.store.as_ref().is_some_and(|s| s.contains(id));
        if !current_on_disk {
            let snap = self.snapshot_resident(id)?;
            let t = Instant::now();
            store_op("store.append", || {
                self.store
                    .as_mut()
                    .expect("store present")
                    .park(id, &snap)
                    .map(|_| ())
            })?;
            let dt = t.elapsed();
            self.obs.store_append.record_duration(dt);
            self.scratch_store_ns += dt.as_nanos() as u64;
        }
        // the snapshot above already read everything out of the live
        // arrays — drop the slot without materializing a second copy
        self.drop_slot(id)?;
        Ok(())
    }

    /// Explicit `park` op: idempotent for already-parked ids.
    fn park(&mut self, id: u64) -> Response {
        if self.slots.contains_key(&id) {
            match self.park_out(id) {
                Ok(()) => Response::Parked { id },
                Err(e) => error_of(e),
            }
        } else if self.store.as_ref().is_some_and(|s| s.contains(id)) {
            Response::Parked { id }
        } else {
            Response::error(format!("no session {id}"))
        }
    }

    /// `replicate` parks a warm-standby copy of a session whose home is
    /// *another* backend: the envelope goes straight to the store,
    /// tag-validated by [`SessionStore::park`] but never decoded into a
    /// live net and never made resident, so a standby at replication
    /// interval K=1 pays one store append per acknowledged op and no
    /// session CPU. Refused when the id is resident here — a backend
    /// must never hold both the live session and its own "replica"
    /// (the parked copy would silently shadow the authoritative state
    /// on the next rehydration).
    fn replicate(&mut self, id: u64, state: &Json) -> Response {
        if self.slots.contains_key(&id) {
            return Response::error(format!(
                "replicate: session {id} is resident on this backend \
                 (a home cannot hold its own replica)"
            ));
        }
        if self.store.is_none() {
            return Response::error(
                "replicate: no store configured (start serve with --store-dir)",
            );
        }
        let t = Instant::now();
        let result = store_op("store.append", || {
            self.store
                .as_mut()
                .expect("store present")
                .park(id, state)
                .map(|_| ())
        });
        let dt = t.elapsed();
        self.obs.store_append.record_duration(dt);
        self.scratch_store_ns += dt.as_nanos() as u64;
        match result {
            Ok(()) => Response::Replicated { id },
            Err(e) => error_of(e),
        }
    }

    /// Graceful-shutdown flush: every resident session moves to the
    /// store. A failed park never aborts the drain — the remaining
    /// sessions still get their chance, and every failure is reported.
    /// Without a store this is a no-op (nothing to flush into).
    fn drain(&mut self) -> Response {
        if self.store.is_none() {
            return Response::Drained {
                flushed: 0,
                errors: Vec::new(),
            };
        }
        let mut ids: Vec<u64> = self.slots.keys().copied().collect();
        ids.sort_unstable();
        let mut flushed = 0;
        let mut errors = Vec::new();
        for id in ids {
            match self.park_out(id) {
                Ok(()) => flushed += 1,
                Err(e) => errors.push(format!("session {id}: {e}")),
            }
        }
        Response::Drained { flushed, errors }
    }

    /// Remove a resident session and hand it back as a scalar
    /// [`Session`], extracting (and re-keying) its batch lane if it was
    /// batched.
    fn take_session(&mut self, id: u64) -> Result<Box<Session>, String> {
        let slot = self
            .slots
            .remove(&id)
            .ok_or_else(|| format!("no session {id}"))?;
        self.untrack(id);
        match slot {
            Slot::Scalar(session) => Ok(session),
            Slot::Batched(key, lane, spec) => {
                let batch = self
                    .batches
                    .get_mut(&key)
                    .expect("batch exists for batched slot");
                // swap_remove is O(one lane's state) under the
                // capacity-padded layout: the removed lane is read
                // straight out of the padded arrays and only the last
                // lane is copied over the hole — the stride (and every
                // surviving lane) stays put, so evict/rehydrate churn
                // costs O(lane), not O(batch).
                let extracted = batch.swap_remove_lane(lane)?;
                let batch_spec = batch.spec().clone();
                // re-key the moved lane *before* the fallible session
                // construction: an error below must not leave lane_ids
                // and the moved session's slot pointing at a dead lane
                self.finish_batched_removal(key, lane, id);
                let session = Session::from_lane(spec, &batch_spec, &extracted)?;
                Ok(Box::new(session))
            }
            Slot::Staged(key, lane, spec) => {
                let batch = self
                    .staged_batches
                    .get_mut(&key)
                    .expect("cohort exists for staged slot");
                let extracted = batch.swap_remove_lane(lane)?;
                let batch_spec = batch.spec().clone();
                // same ordering invariant as the columnar arm: re-key
                // the moved lane before the fallible construction
                self.finish_staged_removal(key, lane, id);
                let session =
                    Session::from_staged_lane(spec, &batch_spec, &extracted)?;
                Ok(Box::new(session))
            }
        }
    }

    /// Drop a resident session's slot without materializing its state —
    /// the evict path, where [`Self::snapshot_resident`] already read
    /// everything out of the live arrays. O(lane) with zero extraction
    /// and no throwaway [`Session`] construction.
    fn drop_slot(&mut self, id: u64) -> Result<(), String> {
        let slot = self
            .slots
            .remove(&id)
            .ok_or_else(|| format!("no session {id}"))?;
        self.untrack(id);
        match slot {
            Slot::Scalar(_) => Ok(()),
            Slot::Batched(key, lane, _) => {
                // a tracked slot's lane index is always in range (the
                // re-key invariant); an out-of-range error here would
                // mean corrupted bookkeeping, where continuing with
                // half-removed state would be worse than stopping
                self.batches
                    .get_mut(&key)
                    .expect("batch exists for batched slot")
                    .discard_lane(lane)
                    .expect("tracked lane index in range");
                self.finish_batched_removal(key, lane, id);
                Ok(())
            }
            Slot::Staged(key, lane, _) => {
                self.staged_batches
                    .get_mut(&key)
                    .expect("cohort exists for staged slot")
                    .discard_lane(lane)
                    .expect("tracked lane index in range");
                self.finish_staged_removal(key, lane, id);
                Ok(())
            }
        }
    }

    /// Post-removal bookkeeping shared by [`Self::take_session`] and
    /// [`Self::drop_slot`]: re-key the session whose lane was swapped
    /// into the hole, retire emptied batches, and compact sparse ones.
    fn finish_batched_removal(&mut self, key: BatchKey, lane: usize, id: u64) {
        // the last lane moved into `lane`: re-key that session
        let ids = self.lane_ids.get_mut(&key).expect("lane ids exist");
        let moved = ids.pop().expect("non-empty lane list");
        let emptied = ids.is_empty();
        if moved != id {
            ids[lane] = moved;
            if let Some(Slot::Batched(_, l, _)) = self.slots.get_mut(&moved) {
                *l = lane;
            }
        }
        if emptied {
            self.batches.remove(&key);
            self.lane_ids.remove(&key);
        } else {
            // cold-path compaction: once removals leave a batch at
            // <= 1/4 occupancy, shrink the padded arrays so a drained
            // population doesn't pin its high-water-mark allocation.
            // Slot order is preserved, so the id->lane map stays valid.
            let batch = self.batches.get_mut(&key).expect("batch still exists");
            if batch.capacity() >= 8 && batch.len() * 4 <= batch.capacity() {
                batch.compact();
            }
        }
    }

    /// Post-removal bookkeeping for staged cohorts, mirroring
    /// [`Self::finish_batched_removal`]. The ordering matters doubly
    /// here: a stage-transition hop swap-removes a lane and then
    /// re-places the session, so the moved lane's re-key must land
    /// *before* the <= 1/4-occupancy compaction below runs — compacting
    /// first would shrink the padded arrays around a lane the id->lane
    /// map still points at, corrupting whichever cohort-mate the hop
    /// happened to swap into the hole.
    fn finish_staged_removal(&mut self, key: StagedKey, lane: usize, id: u64) {
        let ids = self.staged_lane_ids.get_mut(&key).expect("lane ids exist");
        let moved = ids.pop().expect("non-empty lane list");
        let emptied = ids.is_empty();
        if moved != id {
            ids[lane] = moved;
            if let Some(Slot::Staged(_, l, _)) = self.slots.get_mut(&moved) {
                *l = lane;
            }
        }
        if emptied {
            self.staged_batches.remove(&key);
            self.staged_lane_ids.remove(&key);
        } else {
            let batch = self
                .staged_batches
                .get_mut(&key)
                .expect("cohort still exists");
            if batch.capacity() >= 8 && batch.len() * 4 <= batch.capacity() {
                batch.compact();
            }
        }
    }

    /// Stage-transition hop: a staged lane whose clock crossed
    /// `steps_per_stage` leaves its cohort, settles the boundary (the
    /// learning stage freezes, the next one spawns from the lane rng —
    /// [`Session::from_staged_lane`] performs the settle), and is
    /// re-placed. Placement re-discovers capability, so the session
    /// lands in the next stage's cohort, or in the frozen-forever one
    /// once every feature is materialized. The swap-remove/re-key runs
    /// before compaction and before the fallible session rebuild, so an
    /// interleaved eviction or a sparse cohort can never leave the
    /// id->lane map pointing at a dead lane mid-hop. LRU/dirty
    /// bookkeeping survives untouched — the session never leaves
    /// residency, only its slot representation changes.
    fn hop_staged_lane(&mut self, id: u64) -> Result<(), String> {
        let (key, lane, spec) = match self.slots.remove(&id) {
            Some(Slot::Staged(key, lane, spec)) => (key, lane, spec),
            Some(other) => {
                self.slots.insert(id, other);
                return Err(format!("session {id} is not in a staged cohort"));
            }
            None => return Err(format!("no session {id}")),
        };
        let batch = self
            .staged_batches
            .get_mut(&key)
            .expect("cohort exists for staged slot");
        let extracted = batch.swap_remove_lane(lane)?;
        let batch_spec = batch.spec().clone();
        self.finish_staged_removal(key, lane, id);
        let session = Session::from_staged_lane(spec, &batch_spec, &extracted)?;
        self.place(id, session)
    }

    /// Session counts per staged cohort, labeled by learning-stage index
    /// and readout width (`frozen:` once every feature is materialized).
    /// The `stats` reply surfaces these so an operator can watch a
    /// population migrate stage by stage toward the frozen cohort.
    fn cohort_counts(&self) -> Vec<(String, usize)> {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for batch in self.staged_batches.values() {
            let spec = batch.spec();
            let label = if spec.frozen_forever {
                format!("frozen:d{}", spec.d())
            } else {
                format!("stage{}:d{}", spec.stage, spec.d())
            };
            *counts.entry(label).or_insert(0) += batch.len();
        }
        counts.into_iter().collect()
    }

    /// Session counts per learner kind. Resident sessions count under
    /// the spec tag they were opened with (batched slots are always
    /// `columnar`-shaped but report their opening kind); parked sessions
    /// count under their envelope's kind tag, read from the store index
    /// without touching disk.
    fn kind_counts(&self) -> Vec<(String, usize)> {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for slot in self.slots.values() {
            let kind = match slot {
                Slot::Scalar(session) => session.spec().learner.kind(),
                Slot::Batched(_, _, spec) | Slot::Staged(_, _, spec) => {
                    spec.learner.kind()
                }
            };
            *counts.entry(kind.to_string()).or_insert(0) += 1;
        }
        if let Some(store) = &self.store {
            for id in store.ids() {
                if !self.slots.contains_key(&id) {
                    if let Some(kind) = store.kind_of(id) {
                        *counts.entry(kind.to_string()).or_insert(0) += 1;
                    }
                }
            }
        }
        counts.into_iter().collect()
    }

    fn open(&mut self, id: u64, spec: SessionSpec) -> Response {
        match Session::open(spec) {
            Ok(session) => self.insert(id, session),
            Err(e) => Response::error(e),
        }
    }

    /// Admit a brand-new or wire-restored session: place it, mark it
    /// most-recently-used and dirty (the store has no copy yet), and
    /// enforce the resident cap.
    fn insert(&mut self, id: u64, session: Session) -> Response {
        if self.store.as_ref().is_some_and(|s| s.contains(id)) {
            return Response::error(format!("session {id} already exists (parked)"));
        }
        if let Err(e) = self.place(id, session) {
            return Response::error(e);
        }
        self.touch(id);
        self.dirty.insert(id);
        if let Err(e) = self.evict_to_cap() {
            // the open must fail atomically: a session the client never
            // got an id for must not stay resident eating the cap
            let _ = self.drop_slot(id);
            return Response::error(format!("open aborted, eviction failed: {e}"));
        }
        Response::Opened { id }
    }

    /// Place a session into a resident slot: batched representation when
    /// the net's discovered capability allows, scalar otherwise. No LRU
    /// or dirty bookkeeping — callers decide that. Batch insertion is
    /// O(one lane's state) — `push_lane` writes the new session into a
    /// padding slot in place (amortized-doubling growth when full).
    fn place(&mut self, id: u64, session: Session) -> Result<(), String> {
        if self.slots.contains_key(&id) {
            return Err(format!("session {id} already exists"));
        }
        let spec = session.spec().clone();
        if let Some(batch_spec) = session.columnar_batch_spec() {
            let key = batch_key(&batch_spec);
            let lane = session.to_lane()?;
            let batch = match self.batches.entry(key) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(ColumnarSessionBatch::from_lanes(batch_spec, &[])?)
                }
            };
            let idx = batch.push_lane(lane)?;
            self.lane_ids.entry(key).or_default().push(id);
            debug_assert_eq!(self.lane_ids[&key].len(), idx + 1);
            self.slots.insert(id, Slot::Batched(key, idx, spec));
        } else if let Some(batch_spec) = session.staged_batch_spec() {
            let key = staged_key(&batch_spec);
            let lane = session.to_staged_lane()?;
            let batch = match self.staged_batches.entry(key) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(StagedSessionBatch::from_lanes(batch_spec, &[])?)
                }
            };
            let idx = batch.push_lane(lane)?;
            self.staged_lane_ids.entry(key).or_default().push(id);
            debug_assert_eq!(self.staged_lane_ids[&key].len(), idx + 1);
            self.slots.insert(id, Slot::Staged(key, idx, spec));
        } else {
            self.slots.insert(id, Slot::Scalar(Box::new(session)));
        }
        Ok(())
    }

    fn step_session(&mut self, id: u64, x: &[f32], c: f32) -> Result<f32, String> {
        self.ensure_resident(id)?;
        // clock the kernel only: residency (store I/O) is its own stage
        let t = Instant::now();
        let mut staged_hop = false;
        let (y, kind, batched) = match self
            .slots
            .get_mut(&id)
            .ok_or_else(|| format!("no session {id}"))?
        {
            Slot::Scalar(session) => {
                (session.step(x, c)?, session.spec().learner.kind(), false)
            }
            Slot::Batched(key, lane, spec) => {
                if x.len() != spec.n_inputs {
                    return Err(format!(
                        "session expects {} inputs, got {}",
                        spec.n_inputs,
                        x.len()
                    ));
                }
                let y = self
                    .batches
                    .get_mut(key)
                    .expect("batch exists for batched slot")
                    .step_one(*lane, x, c);
                (y, spec.learner.kind(), true)
            }
            Slot::Staged(key, lane, spec) => {
                if x.len() != spec.n_inputs {
                    return Err(format!(
                        "session expects {} inputs, got {}",
                        spec.n_inputs,
                        x.len()
                    ));
                }
                let batch = self
                    .staged_batches
                    .get_mut(key)
                    .expect("cohort exists for staged slot");
                let y = batch.step_one(*lane, x, c);
                staged_hop = batch.lane_pending(*lane);
                (y, spec.learner.kind(), true)
            }
        };
        let dt = t.elapsed();
        if batched {
            self.obs.step_batched.record_duration(dt);
        } else {
            self.obs.step_scalar.record_duration(dt);
        }
        self.scratch_kernel_ns += dt.as_nanos() as u64;
        self.bump_kind_steps(kind, 1);
        self.steps_served += 1;
        self.dirty.insert(id);
        if staged_hop {
            // the crossing step's prediction is already computed (the
            // scalar twin settles its boundary after the TD update of
            // the same step), so hopping now — before any further op
            // can observe the lane — keeps the trajectory bit-identical
            self.hop_staged_lane(id)?;
        }
        Ok(y)
    }

    fn predict_session(&mut self, id: u64, x: &[f32]) -> Result<f32, String> {
        self.ensure_resident(id)?;
        // prediction advances recurrent state, so the disk copy goes stale
        self.dirty.insert(id);
        match self
            .slots
            .get_mut(&id)
            .ok_or_else(|| format!("no session {id}"))?
        {
            Slot::Scalar(session) => session.predict(x),
            Slot::Batched(key, lane, spec) => {
                if x.len() != spec.n_inputs {
                    return Err(format!(
                        "session expects {} inputs, got {}",
                        spec.n_inputs,
                        x.len()
                    ));
                }
                Ok(self
                    .batches
                    .get_mut(key)
                    .expect("batch exists for batched slot")
                    .predict_one(*lane, x))
            }
            Slot::Staged(key, lane, spec) => {
                if x.len() != spec.n_inputs {
                    return Err(format!(
                        "session expects {} inputs, got {}",
                        spec.n_inputs,
                        x.len()
                    ));
                }
                // predict advances recurrent state but never the stage
                // clock (no TD update, no end_step), so no hop check
                Ok(self
                    .staged_batches
                    .get_mut(key)
                    .expect("cohort exists for staged slot")
                    .predict_one(*lane, x))
            }
        }
    }

    /// Step many sessions. Groups that cover an entire SoA batch run
    /// through the fused [`ColumnarSessionBatch::step_all`]; everything
    /// else falls back to per-session stepping. Result order matches
    /// input order.
    fn step_many(&mut self, items: Vec<StepItem>) -> Vec<Result<f32, String>> {
        let n_items = items.len();
        let mut out: Vec<Option<Result<f32, String>>> = vec![None; n_items];
        // rehydrate parked members first so the fused pass can cover
        // them; failures surface per item in the scalar fallback
        for item in &items {
            let _ = self.ensure_resident(item.id);
        }
        // partition: which batch does each item belong to (if any)?
        let mut per_batch: HashMap<BatchKey, Vec<(usize, usize)>> = HashMap::new();
        for (pos, item) in items.iter().enumerate() {
            if let Some(Slot::Batched(key, lane, _)) = self.slots.get(&item.id) {
                per_batch.entry(*key).or_default().push((pos, *lane));
            }
        }
        for (key, members) in per_batch {
            let batch = self.batches.get_mut(&key).expect("batch exists");
            let bsz = batch.len();
            let n = batch.spec().n_inputs;
            // fused path only when every lane is covered exactly once and
            // every observation has the right width
            let full = members.len() == bsz && {
                let mut seen = vec![false; bsz];
                members.iter().all(|&(pos, lane)| {
                    let fresh = !seen[lane];
                    seen[lane] = true;
                    fresh && items[pos].x.len() == n
                })
            };
            if !full {
                continue; // handled by the scalar fallback below
            }
            let mut obs = vec![0.0f32; bsz * n];
            let mut cs = vec![0.0f32; bsz];
            for &(pos, lane) in &members {
                obs[lane * n..(lane + 1) * n].copy_from_slice(&items[pos].x);
                cs[lane] = items[pos].c;
            }
            let t = Instant::now();
            let ys = batch.step_all(&obs, &cs).to_vec();
            let dt = t.elapsed();
            self.obs.step_batched.record_duration(dt);
            self.scratch_kernel_ns += dt.as_nanos() as u64;
            for &(pos, lane) in &members {
                out[pos] = Some(Ok(ys[lane]));
                let id = items[pos].id;
                self.dirty.insert(id);
                // batched slots report their opening kind (see
                // kind_counts): count fused steps under the same tag
                let kind = match self.slots.get(&id) {
                    Some(Slot::Batched(_, _, spec)) => spec.learner.kind(),
                    Some(Slot::Scalar(session)) => session.spec().learner.kind(),
                    None => continue,
                };
                self.bump_kind_steps(kind, 1);
            }
            self.steps_served += bsz as u64;
        }
        // staged cohorts: same fused-coverage discipline, plus the
        // stage-transition hop for every lane whose clock crossed
        // `steps_per_stage` during the pass
        let mut per_staged: HashMap<StagedKey, Vec<(usize, usize)>> =
            HashMap::new();
        for (pos, item) in items.iter().enumerate() {
            if let Some(Slot::Staged(key, lane, _)) = self.slots.get(&item.id) {
                per_staged.entry(*key).or_default().push((pos, *lane));
            }
        }
        let mut hops: Vec<(usize, u64)> = Vec::new();
        for (key, members) in per_staged {
            let batch = self.staged_batches.get_mut(&key).expect("cohort exists");
            let bsz = batch.len();
            let n = batch.spec().n_inputs;
            let full = members.len() == bsz && {
                let mut seen = vec![false; bsz];
                members.iter().all(|&(pos, lane)| {
                    let fresh = !seen[lane];
                    seen[lane] = true;
                    fresh && items[pos].x.len() == n
                })
            };
            if !full {
                continue; // handled by the scalar fallback below
            }
            let mut obs = vec![0.0f32; bsz * n];
            let mut cs = vec![0.0f32; bsz];
            for &(pos, lane) in &members {
                obs[lane * n..(lane + 1) * n].copy_from_slice(&items[pos].x);
                cs[lane] = items[pos].c;
            }
            let t = Instant::now();
            let ys = batch.step_all(&obs, &cs).to_vec();
            let dt = t.elapsed();
            // resolve pending lanes to ids *before* any hop runs: the
            // swap-removes below renumber every recorded lane index
            let pending = batch.pending_lanes().to_vec();
            self.obs.step_batched.record_duration(dt);
            self.scratch_kernel_ns += dt.as_nanos() as u64;
            for &(pos, lane) in &members {
                out[pos] = Some(Ok(ys[lane]));
                let id = items[pos].id;
                self.dirty.insert(id);
                let kind = match self.slots.get(&id) {
                    Some(Slot::Staged(_, _, spec)) => spec.learner.kind(),
                    _ => continue,
                };
                self.bump_kind_steps(kind, 1);
            }
            self.steps_served += bsz as u64;
            let lane_pos: HashMap<usize, usize> =
                members.iter().map(|&(pos, lane)| (lane, pos)).collect();
            for lane in pending {
                let pos = lane_pos[&lane];
                hops.push((pos, items[pos].id));
            }
        }
        // hops run before the scalar fallback: a duplicate item for a
        // hopped id must step the settled next-stage session, exactly
        // as a scalar twin would after its in-step boundary settle
        for (pos, id) in hops {
            if let Err(e) = self.hop_staged_lane(id) {
                out[pos] = Some(Err(e));
            }
        }
        // scalar fallback for everything not answered by a fused pass
        for (pos, item) in items.into_iter().enumerate() {
            if out[pos].is_none() {
                out[pos] = Some(self.step_session(item.id, &item.x, item.c));
            }
        }
        out.into_iter().map(|r| r.expect("every item answered")).collect()
    }

    /// Snapshot a session wherever it lives: resident sessions serialize
    /// their live state; parked sessions return the stored envelope
    /// without rehydrating.
    fn snapshot_session(&mut self, id: u64) -> Result<Json, String> {
        if self.slots.contains_key(&id) {
            self.touch(id);
            return self.snapshot_resident(id);
        }
        if let Some(store) = &self.store {
            if store.contains(id) {
                return store_op("store.load", || store.load(id));
            }
        }
        Err(format!("no session {id}"))
    }

    /// Serialize a resident session (scalar slot or batch lane) into the
    /// versioned envelope; the slot is untouched.
    fn snapshot_resident(&self, id: u64) -> Result<Json, String> {
        match self.slots.get(&id).ok_or_else(|| format!("no session {id}"))? {
            Slot::Scalar(session) => Ok(session.snapshot()),
            Slot::Batched(key, lane, spec) => {
                let batch = self.batches.get(key).expect("batch exists");
                let extracted = batch.extract_lane(*lane);
                let session =
                    Session::from_lane(spec.clone(), batch.spec(), &extracted)?;
                Ok(session.snapshot())
            }
            Slot::Staged(key, lane, spec) => {
                let batch = self.staged_batches.get(key).expect("cohort exists");
                let extracted = batch.extract_lane(*lane);
                let session = Session::from_staged_lane(
                    spec.clone(),
                    batch.spec(),
                    &extracted,
                )?;
                Ok(session.snapshot())
            }
        }
    }

    /// Terminate a session for good, wherever it lives. Parked sessions
    /// report the step count recorded in their envelope — no rehydration
    /// just to say goodbye.
    fn close(&mut self, id: u64) -> Response {
        if self.slots.contains_key(&id) {
            // retire the parked copy *before* dropping the live slot: if
            // the delete fails the session stays resident, instead of a
            // stale envelope surviving to resurrect on a later step
            if let Some(store) = self.store.as_mut() {
                if let Err(e) = store.delete(id) {
                    return error_of(format!("{STORE_ERR}{e}"));
                }
            }
            return match self.take_session(id) {
                Ok(session) => Response::Closed {
                    id,
                    steps: session.steps(),
                },
                Err(e) => Response::error(e),
            };
        }
        let Some(store) = self.store.as_mut() else {
            return Response::error(format!("no session {id}"));
        };
        if !store.contains(id) {
            return Response::error(format!("no session {id}"));
        }
        let steps = match store.load(id) {
            Ok(env) => env
                .get("td")
                .and_then(|t| t.get("steps"))
                .and_then(|s| s.as_f64())
                .unwrap_or(0.0) as u64,
            Err(e) => return error_of(format!("{STORE_ERR}{e}")),
        };
        match store.delete(id) {
            Ok(_) => Response::Closed { id, steps },
            Err(e) => error_of(format!("{STORE_ERR}{e}")),
        }
    }
}

enum Job {
    Run {
        req: Request,
        reply: mpsc::Sender<Response>,
        /// send time — the worker derives the queue-wait stage from it
        enqueued: Instant,
        /// stage breakdown sink for sampled trace events (None = untraced)
        stages: Option<Arc<StageCell>>,
    },
    Shutdown,
}

/// Smallest member of the progression `offset, offset + stride, ...`
/// that is `>= min`. `offset < stride` is a precondition (enforced by
/// [`ShardPool::set_id_scheme`]).
fn align_up(min: u64, offset: u64, stride: u64) -> u64 {
    let rem = min % stride;
    if offset >= rem {
        min + (offset - rem)
    } else {
        min + stride + offset - rem
    }
}

/// N shard worker threads plus the request router. The only shared state
/// is the id allocator and the telemetry registry — sessions live
/// entirely inside their shard.
pub struct ShardPool {
    txs: Vec<mpsc::Sender<Job>>,
    joins: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    /// Fresh ids are minted on the arithmetic progression
    /// `id_offset, id_offset + id_stride, ...` (defaults `0, 1`, i.e.
    /// every id). A cluster of independently-minting backends sets a
    /// disjoint (offset, stride) per process (`ccn serve --id-offset K
    /// --id-stride N`) so public ids never collide across the fleet.
    id_stride: u64,
    id_offset: u64,
    /// Durable id floor (store-backed pools only): an id is burned on
    /// disk before any client sees it, so a crash can never lead to a
    /// reused id — not even for sessions that were never parked.
    watermark: Option<IdWatermark>,
    /// shared telemetry: stage timers and per-kind step counters land
    /// here from every shard worker
    obs: Arc<Registry>,
}

impl ShardPool {
    pub fn new(n_shards: usize) -> Self {
        Self::with_store(n_shards, None)
            .expect("a storeless pool cannot fail to boot")
    }

    /// A pool with the durable tier mounted: shard `k` owns
    /// `<dir>/shard-<k>/`. Boot scans every shard store, adopts sessions
    /// stranded by a different historical shard count, validates that
    /// all parked kinds are restorable by this binary, and starts the id
    /// allocator above every parked id — so a restarted server resumes
    /// exactly where the stores left off.
    pub fn with_store(
        n_shards: usize,
        cfg: Option<StoreConfig>,
    ) -> Result<Self, String> {
        Self::with_store_and_obs(n_shards, cfg, Arc::new(Registry::new()))
    }

    /// [`ShardPool::with_store`] recording into a caller-owned telemetry
    /// registry (the `Service` passes its pre-registered one so shard
    /// stage timers surface through the `metrics` wire op).
    pub fn with_store_and_obs(
        n_shards: usize,
        cfg: Option<StoreConfig>,
        obs: Arc<Registry>,
    ) -> Result<Self, String> {
        let n = n_shards.max(1);
        let (stores, first_id, watermark) = match &cfg {
            None => ((0..n).map(|_| None).collect::<Vec<_>>(), 1, None),
            Some(cfg) => {
                let (stores, max_id) = Self::open_stores(cfg, n)?;
                let wm = IdWatermark::open(cfg.watermark_path())?;
                // parked ids catch crashes of pre-watermark stores; the
                // floor catches ids that were live but never parked
                let first = (max_id + 1).max(wm.floor().max(1));
                (stores.into_iter().map(Some).collect(), first, Some(wm))
            }
        };
        let resident_cap = cfg.as_ref().map_or(0, |c| c.resident_cap);
        let mut txs = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for (k, store) in stores.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Job>();
            txs.push(tx);
            let registry = Arc::clone(&obs);
            joins.push(std::thread::spawn(move || {
                let mut state = ShardState::with_store(store, resident_cap);
                state.set_obs(ShardObs::new(registry));
                let queue_wait = Arc::clone(&state.obs.queue_wait);
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Run {
                            req,
                            reply,
                            enqueued,
                            stages,
                        } => {
                            let waited = enqueued.elapsed();
                            queue_wait.record_duration(waited);
                            let t = Instant::now();
                            let resp = state.handle(req);
                            if let Some(cell) = stages {
                                let exec = t.elapsed();
                                cell.queue_ns
                                    .store(waited.as_nanos() as u64, Ordering::Relaxed);
                                cell.exec_ns
                                    .store(exec.as_nanos() as u64, Ordering::Relaxed);
                                cell.store_ns
                                    .store(state.scratch_store_ns, Ordering::Relaxed);
                                cell.kernel_ns
                                    .store(state.scratch_kernel_ns, Ordering::Relaxed);
                                // write the shard index last: it marks
                                // the cell filled (see StageCell docs)
                                cell.shard.store(k as u64, Ordering::Relaxed);
                            }
                            // receiver may have hung up; that's fine
                            let _ = reply.send(resp);
                        }
                        Job::Shutdown => break,
                    }
                }
            }));
        }
        Ok(Self {
            txs,
            joins,
            next_id: AtomicU64::new(first_id),
            id_stride: 1,
            id_offset: 0,
            watermark,
            obs,
        })
    }

    /// Constrain fresh ids to the progression `offset, offset + stride,
    /// ...` — the cluster tier gives each backend a disjoint residue
    /// class so independently-minting processes never collide. Must be
    /// called before any session exists; the default `(0, 1)` scheme is
    /// bit-identical to a pool that never calls this.
    pub fn set_id_scheme(
        &mut self,
        offset: u64,
        stride: u64,
    ) -> Result<(), String> {
        if stride == 0 {
            return Err("id scheme: stride must be >= 1".to_string());
        }
        if offset >= stride {
            return Err(format!(
                "id scheme: offset {offset} must be < stride {stride}"
            ));
        }
        self.id_stride = stride;
        self.id_offset = offset;
        // Re-align the allocator cursor (which may sit above 1 after a
        // boot scan) onto the progression without ever going below it.
        let cur = self.next_id.load(Ordering::Relaxed);
        self.next_id
            .store(align_up(cur, offset, stride), Ordering::Relaxed);
        Ok(())
    }

    /// The telemetry registry every shard worker records into.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.obs
    }

    /// Open the per-shard stores and reconcile them with the current
    /// shard count: sessions in `shard-<j>/` dirs with `j >= n` (an
    /// earlier run had more shards) and sessions whose `id % n` no
    /// longer matches their directory are re-parked where the router
    /// will look for them. Returns the stores plus the highest parked id.
    fn open_stores(
        cfg: &StoreConfig,
        n: usize,
    ) -> Result<(Vec<SessionStore>, u64), String> {
        std::fs::create_dir_all(&cfg.dir)
            .map_err(|e| format!("store root {}: {e}", cfg.dir.display()))?;
        let mut stores: Vec<SessionStore> = Vec::with_capacity(n);
        for k in 0..n {
            stores.push(SessionStore::open(cfg.shard_dir(k))?);
        }
        // Migration is always park-into-the-new-home *first*, delete the
        // old copy *after*: a crash in between leaves a duplicate (which
        // the next boot's misplaced-id pass resolves), never a loss.
        for entry in std::fs::read_dir(&cfg.dir)
            .map_err(|e| format!("store root list: {e}"))?
        {
            let entry = entry.map_err(|e| format!("store root list: {e}"))?;
            let name = entry.file_name();
            let idx = name
                .to_string_lossy()
                .strip_prefix("shard-")
                .and_then(|s| s.parse::<usize>().ok());
            if let Some(idx) = idx {
                if idx >= n && entry.path().is_dir() {
                    let path = entry.path();
                    let mut orphan = SessionStore::open(&path)?;
                    for (id, env) in orphan.scan()? {
                        stores[(id % n as u64) as usize].park(id, &env)?;
                        orphan.delete(id)?;
                    }
                    drop(orphan);
                    // fully migrated: retire the directory so future
                    // boots stop re-opening and replaying dead records
                    let _ = std::fs::remove_dir_all(&path);
                }
            }
        }
        for k in 0..n {
            let misplaced: Vec<u64> = stores[k]
                .ids()
                .into_iter()
                .filter(|id| (id % n as u64) as usize != k)
                .collect();
            for id in misplaced {
                let env = stores[k].load(id)?;
                stores[(id % n as u64) as usize].park(id, &env)?;
                stores[k].delete(id)?;
            }
        }
        // fail fast on envelopes this binary cannot restore (version
        // skew is a boot-time error, not a mid-traffic surprise)
        let mut unknown: Vec<String> = Vec::new();
        for s in &stores {
            for id in s.ids() {
                if let Some(kind) = s.kind_of(id) {
                    if NetRegistry::family(kind).is_none() {
                        unknown.push(format!("{id}:{kind}"));
                    }
                }
            }
        }
        if !unknown.is_empty() {
            return Err(format!(
                "store holds sessions of unregistered kinds: {}",
                unknown.join(", ")
            ));
        }
        let max_id = stores.iter().flat_map(|s| s.ids()).max().unwrap_or(0);
        Ok((stores, max_id))
    }

    pub fn n_shards(&self) -> usize {
        self.txs.len()
    }

    fn shard_of(&self, id: u64) -> usize {
        (id % self.txs.len() as u64) as usize
    }

    fn call_shard(&self, shard: usize, req: Request) -> Response {
        self.call_shard_traced(shard, req, None)
    }

    fn call_shard_traced(
        &self,
        shard: usize,
        req: Request,
        stages: Option<Arc<StageCell>>,
    ) -> Response {
        // injected enqueue faults happen *before* the mpsc send, so a
        // dropped op provably never reached its shard — the one failure
        // mode that is always safe to retry, hence the retriable error
        match fault::hit("shard.enqueue") {
            Some(FaultAction::Drop) => {
                return Response::error_retriable(
                    "injected shard.enqueue fault: op never reached its shard",
                );
            }
            Some(FaultAction::Delay(ms)) => fault::sleep_ms(ms),
            _ => {}
        }
        let (tx, rx) = mpsc::channel();
        let job = Job::Run {
            req,
            reply: tx,
            enqueued: Instant::now(),
            stages,
        };
        if self.txs[shard].send(job).is_err() {
            return Response::error("shard worker is gone");
        }
        rx.recv()
            .unwrap_or_else(|_| Response::error("shard worker dropped the reply"))
    }

    /// Allocate a fresh session id, durably burning it in the watermark
    /// (store-backed pools) before anyone can see it. Ids advance by
    /// `id_stride` so a clustered pool mints only its own residue class.
    fn alloc_id(&self) -> Result<u64, String> {
        let id = self.next_id.fetch_add(self.id_stride, Ordering::Relaxed);
        if let Some(wm) = &self.watermark {
            wm.ensure_covers(id)
                .map_err(|e| format!("id allocation: {e}"))?;
        }
        Ok(id)
    }

    /// An id minted *elsewhere* (a migrated-in session) is about to live
    /// here: raise the allocator cursor past it — staying on this pool's
    /// own progression — and burn it in the watermark, so a later fresh
    /// mint or a crash/restart can never collide with it.
    fn note_external_id(&self, id: u64) -> Result<(), String> {
        let min_next =
            align_up(id.saturating_add(1), self.id_offset, self.id_stride);
        let mut cur = self.next_id.load(Ordering::Relaxed);
        while cur < min_next {
            match self.next_id.compare_exchange_weak(
                cur,
                min_next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        if let Some(wm) = &self.watermark {
            wm.ensure_covers(id)
                .map_err(|e| format!("external id {id}: {e}"))?;
        }
        Ok(())
    }

    /// Allocate an id and open a session on its shard.
    pub fn open(&self, spec: SessionSpec) -> Response {
        self.open_traced(spec, None)
    }

    /// [`ShardPool::open`] with a stage breakdown sink for traced ops.
    pub fn open_traced(
        &self,
        spec: SessionSpec,
        stages: Option<Arc<StageCell>>,
    ) -> Response {
        if self.txs.is_empty() {
            return Response::error("shard pool is closed");
        }
        match self.alloc_id() {
            Ok(id) => self.call_shard_traced(
                self.shard_of(id),
                Request::Open { id, spec },
                stages,
            ),
            Err(e) => Response::error(e),
        }
    }

    /// Allocate an id and restore a snapshot onto its shard.
    pub fn restore(&self, state: Json) -> Response {
        self.restore_traced(state, None)
    }

    /// [`ShardPool::restore`] with a stage breakdown sink for traced ops.
    pub fn restore_traced(
        &self,
        state: Json,
        stages: Option<Arc<StageCell>>,
    ) -> Response {
        if self.txs.is_empty() {
            return Response::error("shard pool is closed");
        }
        match self.alloc_id() {
            Ok(id) => self.call_shard_traced(
                self.shard_of(id),
                Request::Restore { id, state },
                stages,
            ),
            Err(e) => Response::error(e),
        }
    }

    /// Restore a snapshot *as* a caller-chosen id — the cluster handoff
    /// hook: a session migrating between backends keeps its public id.
    /// The id is recorded as externally minted first, so this pool's own
    /// allocator can never hand it out again.
    pub fn restore_at(&self, id: u64, state: Json) -> Response {
        self.restore_at_traced(id, state, None)
    }

    /// [`ShardPool::restore_at`] with a stage breakdown sink.
    pub fn restore_at_traced(
        &self,
        id: u64,
        state: Json,
        stages: Option<Arc<StageCell>>,
    ) -> Response {
        if self.txs.is_empty() {
            return Response::error("shard pool is closed");
        }
        if id == 0 {
            return Response::error("restore: 'id' must be >= 1");
        }
        if let Err(e) = self.note_external_id(id) {
            return Response::error(e);
        }
        self.call_shard_traced(
            self.shard_of(id),
            Request::Restore { id, state },
            stages,
        )
    }

    /// Park a replica envelope under a caller-chosen id — the
    /// warm-standby hook: the router ships a home backend's post-op
    /// snapshot here so a later `warm {id}` (promotion) can resume it
    /// in place. The id is fenced in the allocator exactly like a
    /// migrated-in session, so this pool can never mint it fresh.
    pub fn replicate_at(&self, id: u64, state: Json) -> Response {
        self.replicate_at_traced(id, state, None)
    }

    /// [`ShardPool::replicate_at`] with a stage breakdown sink.
    pub fn replicate_at_traced(
        &self,
        id: u64,
        state: Json,
        stages: Option<Arc<StageCell>>,
    ) -> Response {
        if self.txs.is_empty() {
            return Response::error("shard pool is closed");
        }
        if id == 0 {
            return Response::error("replicate: 'id' must be >= 1");
        }
        // a failed watermark burn is this standby's disk misbehaving,
        // not a bad request — the router may retry or re-replicate
        if let Err(e) = self.note_external_id(id) {
            return Response::error_retriable(e);
        }
        self.call_shard_traced(
            self.shard_of(id),
            Request::Replicate { id, state },
            stages,
        )
    }

    /// Route a single-session request to its owner.
    pub fn call(&self, req: Request) -> Response {
        self.call_traced(req, None)
    }

    /// [`ShardPool::call`] with a stage breakdown sink for traced ops.
    pub fn call_traced(
        &self,
        req: Request,
        stages: Option<Arc<StageCell>>,
    ) -> Response {
        if self.txs.is_empty() {
            return Response::error("shard pool is closed");
        }
        match req.route_id() {
            Some(id) => self.call_shard_traced(self.shard_of(id), req, stages),
            None => Response::error("request has no routing id"),
        }
    }

    /// Flush every shard's resident sessions to its store (no-op without
    /// a store). Returns how many sessions were written out plus every
    /// per-session failure — a partial flush must never read as a full
    /// one.
    pub fn drain(&self) -> (usize, Vec<String>) {
        let mut flushed = 0;
        let mut errors = Vec::new();
        for s in 0..self.txs.len() {
            match self.call_shard(s, Request::Drain) {
                Response::Drained {
                    flushed: f,
                    errors: e,
                } => {
                    flushed += f;
                    errors.extend(e);
                }
                Response::Error { message, .. } => {
                    errors.push(format!("shard {s}: {message}"))
                }
                other => errors.push(format!("shard {s}: unexpected {other:?}")),
            }
        }
        (flushed, errors)
    }

    /// Graceful, deterministic shutdown: drain every shard, then stop
    /// and join the workers. All requests sent before `close` are
    /// answered (the mpsc queue is FIFO and `Shutdown` goes last);
    /// requests after it get a clean "pool is closed" error instead of a
    /// hang. Idempotent. Returns the number of sessions flushed, or an
    /// error naming every session that could not be flushed (the workers
    /// are shut down and joined either way).
    pub fn close(&mut self) -> Result<usize, String> {
        if self.txs.is_empty() {
            return Ok(0);
        }
        let (flushed, errors) = self.drain();
        for tx in &self.txs {
            let _ = tx.send(Job::Shutdown);
        }
        self.txs.clear();
        for join in self.joins.drain(..) {
            let _ = join.join();
        }
        if errors.is_empty() {
            Ok(flushed)
        } else {
            Err(format!(
                "flushed {flushed} session(s), {} failed: {}",
                errors.len(),
                errors.join("; ")
            ))
        }
    }

    /// Scatter step items to their shards, step all shards *in
    /// parallel*, gather results back into input order. This is the
    /// aggregate hot path: one channel round-trip per shard per tick.
    pub fn step_batch(&self, items: Vec<StepItem>) -> Vec<Result<f32, String>> {
        if self.txs.is_empty() {
            return items
                .iter()
                .map(|_| Err("shard pool is closed".into()))
                .collect();
        }
        let n_items = items.len();
        let mut per_shard: Vec<Vec<usize>> = vec![Vec::new(); self.txs.len()];
        let mut shard_items: Vec<Vec<StepItem>> = vec![Vec::new(); self.txs.len()];
        for (pos, item) in items.into_iter().enumerate() {
            let s = self.shard_of(item.id);
            per_shard[s].push(pos);
            shard_items[s].push(item);
        }
        let mut replies: Vec<Option<mpsc::Receiver<Response>>> =
            (0..self.txs.len()).map(|_| None).collect();
        for (s, batch) in shard_items.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            let job = Job::Run {
                req: Request::StepMany { items: batch },
                reply: tx,
                enqueued: Instant::now(),
                // fan-out spans shards: trace events for step_batch carry
                // the op-level duration only, no single-shard breakdown
                stages: None,
            };
            if self.txs[s].send(job).is_ok() {
                replies[s] = Some(rx);
            }
        }
        let mut out: Vec<Result<f32, String>> =
            vec![Err("unanswered".into()); n_items];
        for (s, rx) in replies.into_iter().enumerate() {
            let Some(rx) = rx else {
                for &pos in &per_shard[s] {
                    out[pos] = Err("shard worker is gone".into());
                }
                continue;
            };
            match rx.recv() {
                Ok(Response::SteppedMany { ys }) => {
                    for (&pos, y) in per_shard[s].iter().zip(ys) {
                        out[pos] = y;
                    }
                }
                Ok(other) => {
                    let msg = match other {
                        Response::Error { message, .. } => message,
                        _ => "unexpected shard reply".into(),
                    };
                    for &pos in &per_shard[s] {
                        out[pos] = Err(msg.clone());
                    }
                }
                Err(_) => {
                    for &pos in &per_shard[s] {
                        out[pos] = Err("shard worker dropped the reply".into());
                    }
                }
            }
        }
        out
    }

    /// Per-shard stats snapshots (resident/parked sessions, steps
    /// served, per-kind counts, store volume, eviction/rehydration
    /// counters).
    pub fn stats(&self) -> Vec<ShardStats> {
        (0..self.txs.len())
            .map(|s| match self.call_shard(s, Request::Stats) {
                Response::Stats(st) => st,
                _ => ShardStats::default(),
            })
            .collect()
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // Deliberately NOT a drain: dropping an unclosed pool is the
        // crash path — only parked state survives, which is what the
        // kill/restart recovery tests rely on. Workers are still joined,
        // so in-flight requests finish and their replies are delivered
        // before drop returns.
        for tx in &self.txs {
            let _ = tx.send(Job::Shutdown);
        }
        self.txs.clear();
        for join in self.joins.drain(..) {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LearnerKind;
    use crate::learn::TdConfig;
    use crate::util::prng::Xoshiro256;

    fn spec(learner: LearnerKind, seed: u64) -> SessionSpec {
        SessionSpec {
            learner,
            n_inputs: 3,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            seed,
        }
    }

    fn open_ok(state: &mut ShardState, id: u64, s: SessionSpec) {
        match state.handle(Request::Open { id, spec: s }) {
            Response::Opened { id: got } => assert_eq!(got, id),
            other => panic!("open failed: {other:?}"),
        }
    }

    #[test]
    fn shard_state_full_lifecycle() {
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Columnar { d: 4 }, 0));
        open_ok(
            &mut st,
            2,
            spec(
                LearnerKind::Ccn {
                    total: 4,
                    per_stage: 2,
                    steps_per_stage: 1000,
                },
                1,
            ),
        );
        assert_eq!(st.n_sessions(), 2);
        // the growing ccn session lives in a stage-keyed cohort, not on
        // the scalar path
        assert!(matches!(st.slots.get(&2), Some(Slot::Staged(..))));
        assert_eq!(st.staged_batches.len(), 1);
        let y = st.step_session(1, &[0.1, 0.2, 0.3], 0.5).unwrap();
        assert!(y.is_finite());
        assert!(st.step_session(9, &[0.0; 3], 0.0).is_err(), "unknown id");
        assert!(st.step_session(1, &[0.0; 2], 0.0).is_err(), "bad width");
        let snap = st.snapshot_session(1).unwrap();
        match st.handle(Request::Restore { id: 3, state: snap }) {
            Response::Opened { id } => assert_eq!(id, 3),
            other => panic!("restore failed: {other:?}"),
        }
        match st.handle(Request::Close { id: 1 }) {
            Response::Closed { id, steps } => {
                assert_eq!(id, 1);
                assert_eq!(steps, 1);
            }
            other => panic!("close failed: {other:?}"),
        }
        assert_eq!(st.n_sessions(), 2);
    }

    #[test]
    fn batched_and_scalar_routes_agree() {
        // same columnar spec through the batched store and through a
        // standalone scalar session: identical predictions.
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Columnar { d: 4 }, 42));
        let mut scalar = Session::open(spec(LearnerKind::Columnar { d: 4 }, 42)).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(0);
        for _ in 0..200 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            let y_shard = st.step_session(1, &x, c).unwrap();
            let y_scalar = scalar.step(&x, c).unwrap();
            assert_eq!(y_shard, y_scalar, "batched lane must equal scalar agent");
        }
    }

    #[test]
    fn step_many_fused_path_matches_fallback() {
        let mk = |st: &mut ShardState| {
            for id in 0..5u64 {
                open_ok(st, id + 1, spec(LearnerKind::Columnar { d: 3 }, id));
            }
        };
        let mut fused = ShardState::new();
        let mut fallback = ShardState::new();
        mk(&mut fused);
        mk(&mut fallback);
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..50 {
            let items: Vec<StepItem> = (0..5u64)
                .map(|id| StepItem {
                    id: id + 1,
                    x: (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                    c: rng.uniform(-0.5, 0.5),
                })
                .collect();
            // fused: all 5 lanes of the batch in one request
            let ys_fused = fused.step_many(items.clone());
            // fallback: one at a time (never a full batch in one call)
            let ys_one: Vec<Result<f32, String>> = items
                .iter()
                .map(|it| fallback.step_session(it.id, &it.x, it.c))
                .collect();
            for (a, b) in ys_fused.iter().zip(&ys_one) {
                assert_eq!(
                    a.as_ref().unwrap(),
                    b.as_ref().unwrap(),
                    "fused and scalar paths must agree"
                );
            }
        }
    }

    #[test]
    fn dense_baselines_serve_on_the_scalar_path() {
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Tbptt { d: 2, k: 5 }, 0));
        open_ok(&mut st, 2, spec(LearnerKind::Snap1 { d: 2 }, 1));
        open_ok(&mut st, 3, spec(LearnerKind::Columnar { d: 2 }, 2));
        assert_eq!(st.batches.len(), 1, "only the columnar session batches");
        assert!(st.staged_batches.is_empty(), "dense baselines never cohort");
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..50 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            for id in 1..=3u64 {
                assert!(st.step_session(id, &x, 0.1).unwrap().is_finite());
            }
        }
        // snapshot/restore a dense session through the shard
        let snap = st.snapshot_session(1).unwrap();
        match st.handle(Request::Restore { id: 9, state: snap }) {
            Response::Opened { id } => assert_eq!(id, 9),
            other => panic!("tbptt restore failed: {other:?}"),
        }
        let kinds = st.kind_counts();
        assert_eq!(
            kinds,
            vec![
                ("columnar".to_string(), 1),
                ("snap1".to_string(), 1),
                ("tbptt".to_string(), 2),
            ]
        );
    }

    #[test]
    fn step_many_reports_per_item_errors() {
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Columnar { d: 3 }, 0));
        let items = vec![
            StepItem {
                id: 1,
                x: vec![0.0; 3],
                c: 0.0,
            },
            StepItem {
                id: 77,
                x: vec![0.0; 3],
                c: 0.0,
            },
        ];
        let ys = st.step_many(items);
        assert!(ys[0].is_ok());
        assert!(ys[1].is_err());
    }

    #[test]
    fn close_rekeys_swapped_batch_lane() {
        let mut st = ShardState::new();
        for id in 1..=3u64 {
            open_ok(&mut st, id, spec(LearnerKind::Columnar { d: 2 }, id));
        }
        // twin of session 3 to verify integrity after the swap
        let mut twin = Session::open(spec(LearnerKind::Columnar { d: 2 }, 3)).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..30 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            for id in 1..=3u64 {
                let y = st.step_session(id, &x, 0.1).unwrap();
                if id == 3 {
                    assert_eq!(y, twin.step(&x, 0.1).unwrap());
                }
            }
        }
        // closing session 1 moves session 3 into lane 0
        st.handle(Request::Close { id: 1 });
        for _ in 0..30 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = st.step_session(3, &x, 0.1).unwrap();
            assert_eq!(y, twin.step(&x, 0.1).unwrap(), "lane re-key broke state");
        }
    }

    #[test]
    fn sparse_batches_compact_without_corrupting_survivors() {
        // grow one columnar batch through several capacity doublings,
        // then close almost everyone: the <=1/4-occupancy compaction
        // must fire without disturbing the survivor's trajectory.
        let mut st = ShardState::new();
        for id in 1..=9u64 {
            open_ok(&mut st, id, spec(LearnerKind::Columnar { d: 2 }, id));
        }
        let survivor = 9u64;
        let mut twin = Session::open(spec(LearnerKind::Columnar { d: 2 }, survivor))
            .unwrap();
        let mut rng = Xoshiro256::seed_from_u64(13);
        for _ in 0..25 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            for id in 1..=9u64 {
                let y = st.step_session(id, &x, 0.2).unwrap();
                if id == survivor {
                    assert_eq!(y, twin.step(&x, 0.2).unwrap());
                }
            }
        }
        // close 8 of 9: repeated swap-removes move the survivor around
        // and eventually trigger compaction of the padded arrays
        for id in 1..=8u64 {
            match st.handle(Request::Close { id }) {
                Response::Closed { .. } => {}
                other => panic!("close failed: {other:?}"),
            }
        }
        assert_eq!(st.n_sessions(), 1);
        for _ in 0..25 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = st.step_session(survivor, &x, 0.2).unwrap();
            assert_eq!(y, twin.step(&x, 0.2).unwrap(), "compaction broke state");
        }
    }

    fn fresh_dir(tag: &str) -> std::path::PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        std::env::temp_dir().join(format!(
            "ccn-shard-{tag}-{}-{nanos}",
            std::process::id()
        ))
    }

    fn fresh_store(tag: &str) -> (std::path::PathBuf, SessionStore) {
        let dir = fresh_dir(tag);
        let store = SessionStore::open(&dir).unwrap();
        (dir, store)
    }

    #[test]
    fn lru_evicts_coldest_and_rehydrates_bit_exact() {
        let (dir, store) = fresh_store("lru");
        let mut st = ShardState::with_store(Some(store), 2);
        let mut twins = Vec::new();
        for id in 1..=3u64 {
            open_ok(&mut st, id, spec(LearnerKind::Columnar { d: 3 }, id));
            twins.push(Session::open(spec(LearnerKind::Columnar { d: 3 }, id)).unwrap());
        }
        // cap 2: opening the third evicted the coldest (session 1)
        assert_eq!(st.n_sessions(), 2);
        let stats = st.stats();
        assert_eq!(stats.sessions, 3, "evicted sessions still count");
        assert_eq!(stats.resident, 2);
        assert_eq!(stats.parked, 1);
        assert_eq!(stats.evictions, 1);
        assert!(stats.store_bytes > 0);
        // round-robin stepping churns sessions through the store; every
        // prediction must match the never-evicted twin exactly
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..60 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            for (i, twin) in twins.iter_mut().enumerate() {
                let id = i as u64 + 1;
                let y = st.step_session(id, &x, c).unwrap();
                assert_eq!(y, twin.step(&x, c).unwrap(), "session {id}");
            }
            assert!(st.n_sessions() <= 2, "cap respected");
        }
        let stats = st.stats();
        assert_eq!(stats.sessions, 3);
        assert!(stats.rehydrations > 0, "churn must have rehydrated");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn explicit_park_warm_snapshot_and_close_for_parked_sessions() {
        let (dir, store) = fresh_store("parkwarm");
        let mut st = ShardState::with_store(Some(store), 0);
        open_ok(&mut st, 1, spec(LearnerKind::Columnar { d: 2 }, 0));
        open_ok(&mut st, 2, spec(LearnerKind::Tbptt { d: 2, k: 4 }, 1));
        for _ in 0..20 {
            st.step_session(1, &[0.1, 0.2, 0.3], 0.1).unwrap();
            st.step_session(2, &[0.1, 0.2, 0.3], 0.1).unwrap();
        }
        // park both (batched and scalar slots)
        for id in 1..=2u64 {
            match st.handle(Request::Park { id }) {
                Response::Parked { id: got } => assert_eq!(got, id),
                other => panic!("park failed: {other:?}"),
            }
        }
        assert_eq!(st.n_sessions(), 0);
        // parked sessions still snapshot (straight from the store) and
        // count in stats/kinds
        let snap = st.snapshot_session(1).unwrap();
        assert_eq!(snap.get("kind").and_then(|k| k.as_str()), Some("columnar"));
        let stats = st.stats();
        assert_eq!(stats.parked, 2);
        assert!(stats
            .kinds
            .iter()
            .any(|(k, n)| k == "tbptt" && *n == 1));
        // park again: idempotent
        match st.handle(Request::Park { id: 1 }) {
            Response::Parked { .. } => {}
            other => panic!("re-park failed: {other:?}"),
        }
        // warm rehydrates exactly once
        match st.handle(Request::Warm { id: 1 }) {
            Response::Warmed { rehydrated, .. } => assert!(rehydrated),
            other => panic!("warm failed: {other:?}"),
        }
        match st.handle(Request::Warm { id: 1 }) {
            Response::Warmed { rehydrated, .. } => assert!(!rehydrated),
            other => panic!("re-warm failed: {other:?}"),
        }
        // closing a parked session reports its recorded step count
        match st.handle(Request::Close { id: 2 }) {
            Response::Closed { id, steps } => {
                assert_eq!(id, 2);
                assert_eq!(steps, 20);
            }
            other => panic!("close parked failed: {other:?}"),
        }
        assert!(st.step_session(2, &[0.0; 3], 0.0).is_err(), "closed for good");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn park_without_store_errors_cleanly() {
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Snap1 { d: 2 }, 0));
        match st.handle(Request::Park { id: 1 }) {
            Response::Error { message, .. } => {
                assert!(message.contains("store"), "{message}")
            }
            other => panic!("expected error, got {other:?}"),
        }
        // the session is untouched
        assert!(st.step_session(1, &[0.0; 3], 0.0).is_ok());
    }

    #[test]
    fn pool_close_is_deterministic_and_idempotent() {
        let dir = fresh_dir("close");
        let cfg = StoreConfig::new(&dir, 0);
        let mut pool = ShardPool::with_store(2, Some(cfg.clone())).unwrap();
        let mut ids = Vec::new();
        for s in 0..4u64 {
            match pool.open(spec(LearnerKind::Columnar { d: 2 }, s)) {
                Response::Opened { id } => ids.push(id),
                other => panic!("open failed: {other:?}"),
            }
        }
        for &id in &ids {
            match pool.call(Request::Step {
                id,
                x: vec![0.1, 0.2, 0.3],
                c: 0.0,
            }) {
                Response::Stepped { .. } => {}
                other => panic!("step failed: {other:?}"),
            }
        }
        // close flushes every resident session and joins the workers
        assert_eq!(pool.close().unwrap(), 4);
        assert_eq!(pool.close().unwrap(), 0, "second close is a no-op");
        // requests after close fail cleanly instead of hanging/panicking
        match pool.call(Request::Step {
            id: ids[0],
            x: vec![0.0; 3],
            c: 0.0,
        }) {
            Response::Error { message, .. } => assert!(message.contains("closed")),
            other => panic!("expected closed error, got {other:?}"),
        }
        let ys = pool.step_batch(vec![StepItem {
            id: ids[0],
            x: vec![0.0; 3],
            c: 0.0,
        }]);
        assert!(ys[0].is_err());
        match pool.open(spec(LearnerKind::Columnar { d: 2 }, 9)) {
            Response::Error { .. } => {}
            other => panic!("expected closed error, got {other:?}"),
        }
        drop(pool);
        // a fresh pool on the same store resumes all four, parked
        let pool = ShardPool::with_store(2, Some(cfg)).unwrap();
        let stats = pool.stats();
        assert_eq!(stats.iter().map(|s| s.parked).sum::<usize>(), 4);
        assert_eq!(stats.iter().map(|s| s.resident).sum::<usize>(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn boot_adopts_sessions_from_a_different_shard_count() {
        let dir = fresh_dir("reshard");
        let cfg = StoreConfig::new(&dir, 0);
        // park 6 sessions on a 3-shard pool
        let mut pool = ShardPool::with_store(3, Some(cfg.clone())).unwrap();
        let mut ids = Vec::new();
        for s in 0..6u64 {
            match pool.open(spec(LearnerKind::Columnar { d: 2 }, s)) {
                Response::Opened { id } => ids.push(id),
                other => panic!("open failed: {other:?}"),
            }
        }
        assert_eq!(pool.close().unwrap(), 6);
        drop(pool);
        // reboot with 2 shards: every session must still be reachable
        let pool = ShardPool::with_store(2, Some(cfg)).unwrap();
        let stats = pool.stats();
        assert_eq!(stats.iter().map(|s| s.sessions).sum::<usize>(), 6);
        for &id in &ids {
            match pool.call(Request::Step {
                id,
                x: vec![0.1, 0.2, 0.3],
                c: 0.0,
            }) {
                Response::Stepped { y } => assert!(y.is_finite()),
                other => panic!("resharded step failed: {other:?}"),
            }
        }
        // new ids never collide with parked ones
        match pool.open(spec(LearnerKind::Columnar { d: 2 }, 9)) {
            Response::Opened { id } => assert!(id > *ids.iter().max().unwrap()),
            other => panic!("open failed: {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pool_routes_and_parallel_steps() {
        let pool = ShardPool::new(3);
        let mut ids = Vec::new();
        for s in 0..6u64 {
            match pool.open(spec(LearnerKind::Columnar { d: 3 }, s)) {
                Response::Opened { id } => ids.push(id),
                other => panic!("open failed: {other:?}"),
            }
        }
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..20 {
            let items: Vec<StepItem> = ids
                .iter()
                .map(|&id| StepItem {
                    id,
                    x: (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                    c: 0.1,
                })
                .collect();
            let ys = pool.step_batch(items);
            assert!(ys.iter().all(|y| y.is_ok()));
        }
        let stats = pool.stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(stats.iter().map(|s| s.sessions).sum::<usize>(), 6);
        assert_eq!(
            stats.iter().map(|s| s.steps).sum::<u64>(),
            6 * 20,
            "every step accounted"
        );
        // snapshot through the pool round-trips
        let snap = match pool.call(Request::Snapshot { id: ids[0] }) {
            Response::Snapshotted { state } => state,
            other => panic!("snapshot failed: {other:?}"),
        };
        match pool.restore(snap) {
            Response::Opened { .. } => {}
            other => panic!("restore failed: {other:?}"),
        }
    }

    #[test]
    fn align_up_lands_on_the_progression() {
        // stride 1: identity for any offset-0 progression
        assert_eq!(align_up(1, 0, 1), 1);
        assert_eq!(align_up(17, 0, 1), 17);
        // stride 4, offset 1: 1, 5, 9, ...
        assert_eq!(align_up(0, 1, 4), 1);
        assert_eq!(align_up(1, 1, 4), 1);
        assert_eq!(align_up(2, 1, 4), 5);
        assert_eq!(align_up(5, 1, 4), 5);
        assert_eq!(align_up(6, 1, 4), 9);
        // stride 2, offset 0: evens
        assert_eq!(align_up(1, 0, 2), 2);
        assert_eq!(align_up(2, 0, 2), 2);
        assert_eq!(align_up(3, 0, 2), 4);
    }

    #[test]
    fn id_scheme_mints_only_its_residue_class() {
        let mut pool = ShardPool::new(2);
        assert!(pool.set_id_scheme(1, 0).is_err(), "stride 0 refused");
        assert!(pool.set_id_scheme(4, 4).is_err(), "offset >= stride refused");
        pool.set_id_scheme(1, 4).unwrap();
        let mut ids = Vec::new();
        for s in 0..3u64 {
            match pool.open(spec(LearnerKind::Columnar { d: 3 }, s)) {
                Response::Opened { id } => ids.push(id),
                other => panic!("open failed: {other:?}"),
            }
        }
        assert_eq!(ids, vec![1, 5, 9], "offset 1, stride 4 progression");
    }

    #[test]
    fn staged_sessions_batch_and_hop_matching_scalar_twins() {
        // ccn/constructive sessions live in stage-keyed cohorts; driving
        // them through every stage boundary (two cohort hops for the ccn
        // spec, three for the constructive one, ending frozen-forever)
        // must stay bit-identical to never-batched scalar twins
        let mut st = ShardState::new();
        let specs = [
            spec(
                LearnerKind::Ccn {
                    total: 4,
                    per_stage: 2,
                    steps_per_stage: 25,
                },
                1,
            ),
            spec(
                LearnerKind::Ccn {
                    total: 4,
                    per_stage: 2,
                    steps_per_stage: 25,
                },
                2,
            ),
            spec(
                LearnerKind::Constructive {
                    total: 3,
                    steps_per_stage: 25,
                },
                3,
            ),
        ];
        let mut twins = Vec::new();
        for (i, s) in specs.iter().enumerate() {
            open_ok(&mut st, i as u64 + 1, s.clone());
            twins.push(Session::open(s.clone()).unwrap());
        }
        // the two same-spec ccn sessions share one cohort; the
        // constructive session gets its own
        assert_eq!(st.staged_batches.len(), 2);
        assert!(st.batches.is_empty(), "staged sessions are not columnar");
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..80 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            for (i, twin) in twins.iter_mut().enumerate() {
                let y = st.step_session(i as u64 + 1, &x, c).unwrap();
                assert_eq!(y, twin.step(&x, c).unwrap(), "session {}", i + 1);
            }
        }
        // 80 steps at 25/stage: everyone is frozen-forever now, and the
        // cohort counts say so
        let cohorts = st.cohort_counts();
        assert_eq!(
            cohorts,
            vec![("frozen:d3".to_string(), 1), ("frozen:d4".to_string(), 2)],
            "{cohorts:?}"
        );
    }

    #[test]
    fn staged_fused_step_many_matches_scalar_twins_across_hops() {
        // a full-coverage StepMany takes the fused StagedSessionBatch
        // path; the whole cohort crosses its stage boundary inside one
        // fused pass and every lane hops before the next request
        let mk = |seed: u64| {
            spec(
                LearnerKind::Ccn {
                    total: 4,
                    per_stage: 2,
                    steps_per_stage: 20,
                },
                seed,
            )
        };
        let mut st = ShardState::new();
        let mut twins = Vec::new();
        for id in 1..=4u64 {
            open_ok(&mut st, id, mk(id));
            twins.push(Session::open(mk(id)).unwrap());
        }
        let mut rng = Xoshiro256::seed_from_u64(23);
        for _ in 0..50 {
            let items: Vec<StepItem> = (1..=4u64)
                .map(|id| StepItem {
                    id,
                    x: (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                    c: rng.uniform(-0.5, 0.5),
                })
                .collect();
            let ys = st.step_many(items.clone());
            for (i, twin) in twins.iter_mut().enumerate() {
                assert_eq!(
                    *ys[i].as_ref().unwrap(),
                    twin.step(&items[i].x, items[i].c).unwrap(),
                    "fused staged pass must equal the scalar twin"
                );
            }
        }
        // boundary crossings at 20 and 40: the whole population moved
        // through stage 1 into the frozen-forever cohort
        assert_eq!(
            st.cohort_counts(),
            vec![("frozen:d4".to_string(), 4)]
        );
    }

    #[test]
    fn restore_replaces_sessions_and_migrates_capability_residency() {
        // capability is re-evaluated on every restore: an envelope whose
        // net reports a different BatchCapability migrates the session
        // between scalar and batched residency instead of stranding it
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Tbptt { d: 2, k: 4 }, 0));
        open_ok(&mut st, 2, spec(LearnerKind::Columnar { d: 3 }, 1));
        for _ in 0..10 {
            st.step_session(1, &[0.1, 0.2, 0.3], 0.1).unwrap();
            st.step_session(2, &[0.1, 0.2, 0.3], 0.1).unwrap();
        }
        assert!(matches!(st.slots.get(&1), Some(Slot::Scalar(_))));
        // a columnar envelope restored AT the dense id replaces the
        // tbptt session and lands on the batched path
        let columnar_snap = st.snapshot_session(2).unwrap();
        let mut twin = Session::from_snapshot(&columnar_snap).unwrap();
        match st.handle(Request::Restore {
            id: 1,
            state: columnar_snap,
        }) {
            Response::Opened { id } => assert_eq!(id, 1),
            other => panic!("replace-restore failed: {other:?}"),
        }
        assert!(
            matches!(st.slots.get(&1), Some(Slot::Batched(..))),
            "restored columnar session must join the batch"
        );
        for _ in 0..20 {
            let y = st.step_session(1, &[0.3, -0.1, 0.2], 0.05).unwrap();
            assert_eq!(y, twin.step(&[0.3, -0.1, 0.2], 0.05).unwrap());
        }
        // the flip reversed: a ccn envelope over the columnar id pulls
        // it out of the columnar batch and into a staged cohort
        let mut ccn = Session::open(spec(
            LearnerKind::Ccn {
                total: 4,
                per_stage: 2,
                steps_per_stage: 50,
            },
            9,
        ))
        .unwrap();
        for _ in 0..5 {
            ccn.step(&[0.1, 0.0, -0.2], 0.1).unwrap();
        }
        match st.handle(Request::Restore {
            id: 2,
            state: ccn.snapshot(),
        }) {
            Response::Opened { id } => assert_eq!(id, 2),
            other => panic!("flip-restore failed: {other:?}"),
        }
        assert!(matches!(st.slots.get(&2), Some(Slot::Staged(..))));
        for _ in 0..60 {
            // crosses the stage boundary at 50: the replaced session
            // hops cohorts on the restored clock
            let y = st.step_session(2, &[0.2, 0.1, 0.0], 0.2).unwrap();
            assert_eq!(y, ccn.step(&[0.2, 0.1, 0.0], 0.2).unwrap());
        }
        // a malformed envelope must leave the existing session untouched
        match st.handle(Request::Restore {
            id: 2,
            state: Json::Null,
        }) {
            Response::Error { .. } => {}
            other => panic!("bad envelope accepted: {other:?}"),
        }
        assert!(st.step_session(2, &[0.0; 3], 0.0).is_ok());
    }

    #[test]
    fn cohort_hop_survives_interleaved_evictions_and_compaction() {
        // 9 cohort-mates drive the stage-0 batch through capacity
        // doublings; closing most right before the freeze boundary puts
        // the batch at the <=1/4-occupancy compaction threshold, so the
        // survivors' stage-transition hops interleave with compact() —
        // the hop's id->lane re-keying must come through unscathed, as
        // must a cohort-mate parked one step before the boundary
        let (dir, store) = fresh_store("staged-hop");
        let mut st = ShardState::with_store(Some(store), 0);
        let sps = 30u64;
        let mk = |seed: u64| {
            spec(
                LearnerKind::Ccn {
                    total: 4,
                    per_stage: 2,
                    steps_per_stage: sps,
                },
                seed,
            )
        };
        let mut twins = Vec::new();
        for id in 1..=9u64 {
            open_ok(&mut st, id, mk(id));
            twins.push(Session::open(mk(id)).unwrap());
        }
        let mut rng = Xoshiro256::seed_from_u64(31);
        // every stage clock lands one step before the boundary
        for _ in 0..(sps - 1) {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            for id in 1..=9u64 {
                let y = st.step_session(id, &x, c).unwrap();
                assert_eq!(y, twins[id as usize - 1].step(&x, c).unwrap());
            }
        }
        // close 5 of 9: occupancy 4/16 fires the compaction
        for id in 1..=5u64 {
            match st.handle(Request::Close { id }) {
                Response::Closed { .. } => {}
                other => panic!("close failed: {other:?}"),
            }
        }
        // evict a cohort-mate one step before its freeze boundary
        match st.handle(Request::Park { id: 6 }) {
            Response::Parked { .. } => {}
            other => panic!("park failed: {other:?}"),
        }
        // the resident lanes cross the boundary and hop out of the
        // just-compacted cohort one by one (the first hop's removal
        // lands exactly on the compaction threshold again)
        for _ in 0..40 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            for id in 7..=9u64 {
                let y = st.step_session(id, &x, c).unwrap();
                assert_eq!(
                    y,
                    twins[id as usize - 1].step(&x, c).unwrap(),
                    "session {id} diverged across hop/compaction"
                );
            }
        }
        // the parked lane rehydrates into a fresh stage-0 cohort, hops
        // on its own clock, and stays bit-exact
        match st.handle(Request::Warm { id: 6 }) {
            Response::Warmed { rehydrated, .. } => assert!(rehydrated),
            other => panic!("warm failed: {other:?}"),
        }
        for _ in 0..40 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            let y = st.step_session(6, &x, c).unwrap();
            assert_eq!(y, twins[5].step(&x, c).unwrap(), "rehydrated mate");
        }
        // all four survivors finished their migration to frozen-forever
        assert_eq!(
            st.cohort_counts(),
            vec![("frozen:d4".to_string(), 4)]
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_staged_cohorts_are_bit_exact() {
        use crate::util::check::check;
        // random step/park/warm/close interleavings over a mixed
        // ccn+constructive population behind a small LRU cap, with a
        // forced eviction one step before every freeze boundary: every
        // prediction must be bit-identical to a never-batched twin
        check("staged cohorts bit-exact", 10, |g| {
            let sps = g.usize_in(4, 9) as u64;
            let (dir, store) = fresh_store("staged-prop");
            let mut st = ShardState::with_store(Some(store), 3);
            let specs = [
                spec(
                    LearnerKind::Ccn {
                        total: 4,
                        per_stage: 2,
                        steps_per_stage: sps,
                    },
                    1,
                ),
                spec(
                    LearnerKind::Ccn {
                        total: 4,
                        per_stage: 2,
                        steps_per_stage: sps,
                    },
                    2,
                ),
                spec(
                    LearnerKind::Constructive {
                        total: 3,
                        steps_per_stage: sps,
                    },
                    3,
                ),
                spec(
                    LearnerKind::Constructive {
                        total: 3,
                        steps_per_stage: sps,
                    },
                    4,
                ),
            ];
            let mut twins: Vec<Option<Session>> = Vec::new();
            for (i, s) in specs.iter().enumerate() {
                open_ok(&mut st, i as u64 + 1, s.clone());
                twins.push(Some(Session::open(s.clone()).unwrap()));
            }
            // cross every boundary, through the final freeze
            let total = (sps as usize) * 3 + 2;
            for t in 0..total {
                // an eviction landing one step before a freeze boundary:
                // the parked lane must hop correctly after rehydration
                if t as u64 % sps == sps - 1 {
                    let id = g.usize_in(1, 4) as u64;
                    if twins[id as usize - 1].is_some() {
                        match st.handle(Request::Park { id }) {
                            Response::Parked { .. } => {}
                            other => return Err(format!("park {id}: {other:?}")),
                        }
                    }
                }
                // random park/warm churn on top of the LRU-cap evictions
                if g.usize_in(0, 5) == 0 {
                    let id = g.usize_in(1, 4) as u64;
                    if twins[id as usize - 1].is_some() {
                        let _ = st.handle(Request::Park { id });
                        if g.bool() {
                            let _ = st.handle(Request::Warm { id });
                        }
                    }
                }
                // close one session mid-run, exactly once
                if t == total / 2 && twins[3].is_some() {
                    match st.handle(Request::Close { id: 4 }) {
                        Response::Closed { .. } => twins[3] = None,
                        other => return Err(format!("close: {other:?}")),
                    }
                }
                let x = g.f32_vec(3, -1.0, 1.0);
                let c = g.f32_in(-0.5, 0.5);
                for id in 1..=4u64 {
                    let Some(twin) = twins[id as usize - 1].as_mut() else {
                        continue;
                    };
                    let y = st
                        .step_session(id, &x, c)
                        .map_err(|e| format!("step {id} at t={t}: {e}"))?;
                    let want = twin
                        .step(&x, c)
                        .map_err(|e| format!("twin {id} at t={t}: {e}"))?;
                    if y != want {
                        return Err(format!(
                            "session {id} diverged at t={t}: {y} vs {want}"
                        ));
                    }
                }
            }
            // snapshots round-trip from whatever residency each ended in
            for id in 1..=3u64 {
                let snap = st
                    .snapshot_session(id)
                    .map_err(|e| format!("snapshot {id}: {e}"))?;
                Session::from_snapshot(&snap)
                    .map_err(|e| format!("roundtrip {id}: {e}"))?;
            }
            std::fs::remove_dir_all(&dir).ok();
            Ok(())
        });
    }

    #[test]
    fn restore_at_keeps_the_public_id_and_fences_the_allocator() {
        let pool = ShardPool::new(2);
        let id = match pool.open(spec(LearnerKind::Columnar { d: 3 }, 7)) {
            Response::Opened { id } => id,
            other => panic!("open failed: {other:?}"),
        };
        let snap = match pool.call(Request::Snapshot { id }) {
            Response::Snapshotted { state } => state,
            other => panic!("snapshot failed: {other:?}"),
        };

        // a second pool adopts the session under an explicit higher id
        let dest = ShardPool::new(2);
        match dest.restore_at(0, snap.clone()) {
            Response::Error { message, .. } => {
                assert!(message.contains(">= 1"), "{message}")
            }
            other => panic!("id 0 must be refused: {other:?}"),
        }
        match dest.restore_at(77, snap) {
            Response::Opened { id } => assert_eq!(id, 77),
            other => panic!("restore_at failed: {other:?}"),
        }
        // the adopted session is live under its migrated id
        match dest.call(Request::Step {
            id: 77,
            x: vec![0.1, -0.2, 0.3],
            c: 0.5,
        }) {
            Response::Stepped { .. } => {}
            other => panic!("step after restore_at failed: {other:?}"),
        }
        // fresh mints jump past the adopted id — no collision possible
        match dest.open(spec(LearnerKind::Columnar { d: 3 }, 8)) {
            Response::Opened { id } => assert!(id > 77, "got {id}"),
            other => panic!("open failed: {other:?}"),
        }
    }

    #[test]
    fn replicate_at_parks_a_standby_and_promotes_bit_exact() {
        // a "home" pool accumulates some state and snapshots it
        let home = ShardPool::new(1);
        let id = match home.open(spec(LearnerKind::Columnar { d: 3 }, 5)) {
            Response::Opened { id } => id,
            other => panic!("open failed: {other:?}"),
        };
        for _ in 0..7 {
            match home.call(Request::Step {
                id,
                x: vec![0.1, -0.2, 0.3],
                c: 0.4,
            }) {
                Response::Stepped { .. } => {}
                other => panic!("step failed: {other:?}"),
            }
        }
        let snap = match home.call(Request::Snapshot { id }) {
            Response::Snapshotted { state } => state,
            other => panic!("snapshot failed: {other:?}"),
        };

        // a storeless standby has nowhere to park a replica: terminal
        // error, not retriable (retrying cannot grow it a disk)
        let storeless = ShardPool::new(1);
        match storeless.replicate_at(41, snap.clone()) {
            Response::Error { message, retriable } => {
                assert!(message.contains("store"), "{message}");
                assert!(!retriable, "missing store is not retriable");
            }
            other => panic!("expected error: {other:?}"),
        }

        // the real standby parks the copy without making it resident
        let dir = fresh_dir("replica");
        let standby =
            ShardPool::with_store(2, Some(StoreConfig::new(&dir, 0))).unwrap();
        match standby.replicate_at(0, snap.clone()) {
            Response::Error { message, .. } => {
                assert!(message.contains(">= 1"), "{message}")
            }
            other => panic!("id 0 must be refused: {other:?}"),
        }
        match standby.replicate_at(41, snap.clone()) {
            Response::Replicated { id } => assert_eq!(id, 41),
            other => panic!("replicate_at failed: {other:?}"),
        }
        // re-replication (the next K-boundary) overwrites in place
        match standby.replicate_at(41, snap.clone()) {
            Response::Replicated { id } => assert_eq!(id, 41),
            other => panic!("re-replicate failed: {other:?}"),
        }
        let totals = standby.stats();
        assert_eq!(totals.iter().map(|s| s.resident).sum::<usize>(), 0);
        assert_eq!(totals.iter().map(|s| s.parked).sum::<usize>(), 1);

        // promotion = warm: the replica rehydrates under its public id
        // and continues bit-exactly in lockstep with the home session
        match standby.call(Request::Warm { id: 41 }) {
            Response::Warmed { rehydrated, .. } => assert!(rehydrated),
            other => panic!("promote warm failed: {other:?}"),
        }
        let x = vec![0.3, 0.1, -0.4];
        let on_home = match home.call(Request::Step {
            id,
            x: x.clone(),
            c: -0.2,
        }) {
            Response::Stepped { y } => y,
            other => panic!("home step failed: {other:?}"),
        };
        let on_standby = match standby.call(Request::Step {
            id: 41,
            x,
            c: -0.2,
        }) {
            Response::Stepped { y } => y,
            other => panic!("standby step failed: {other:?}"),
        };
        assert_eq!(on_home, on_standby, "promoted replica diverged");

        // once the session is live here, replicating *onto* it is a
        // refused shadow-write
        match standby.replicate_at(41, snap) {
            Response::Error { message, .. } => {
                assert!(message.contains("resident"), "{message}")
            }
            other => panic!("resident replicate must fail: {other:?}"),
        }
        // and the allocator was fenced past the replica id
        match standby.open(spec(LearnerKind::Columnar { d: 3 }, 9)) {
            Response::Opened { id } => assert!(id > 41, "got {id}"),
            other => panic!("open failed: {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
