//! Sharded session ownership: N worker threads, each owning a disjoint
//! set of sessions behind an mpsc queue.
//!
//! Sessions are routed by `id % n_shards`, so a session's state is only
//! ever touched by its owning shard — the hot path takes no locks.
//! Within a shard, sessions whose net reports
//! [`crate::nets::BatchCapability::Columnar`] live in SoA
//! [`ColumnarSessionBatch`]es keyed by their shape; a `StepMany` request
//! that covers a whole batch advances it in one fused pass. Everything
//! else (growing CCN/constructive sessions, dense baselines, partial
//! batches) takes the scalar path. Both paths produce identical numbers —
//! membership is a performance decision, never a semantic one.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::util::json::Json;

use super::batch::{ColumnarBatchSpec, ColumnarSessionBatch};
use super::protocol::{Request, Response, ShardStats, StepItem};
use super::session::{Session, SessionSpec};

/// Hashable key for "sessions with this shape can share a batch":
/// (n_inputs, d, alpha, gamma, lambda, eps, beta) with floats by bit
/// pattern. Every shape-defining field of [`ColumnarBatchSpec`] must
/// appear here — beta included, since a restored snapshot may carry a
/// non-default normalizer beta.
type BatchKey = (usize, usize, u32, u32, u32, u32, u32);

fn batch_key(spec: &ColumnarBatchSpec) -> BatchKey {
    (
        spec.n_inputs,
        spec.d,
        spec.td.alpha.to_bits(),
        spec.td.gamma.to_bits(),
        spec.td.lambda.to_bits(),
        spec.eps.to_bits(),
        spec.beta.to_bits(),
    )
}

/// Where a session's state lives inside a shard.
enum Slot {
    Scalar(Box<Session>),
    /// `(batch key, lane index)` — the spec is kept for snapshots.
    Batched(BatchKey, usize, SessionSpec),
}

/// Single-threaded session store; one per worker thread.
#[derive(Default)]
pub struct ShardState {
    slots: HashMap<u64, Slot>,
    batches: HashMap<BatchKey, ColumnarSessionBatch>,
    /// lane index -> session id, per batch (to re-key on swap-remove and
    /// to detect full-batch coverage)
    lane_ids: HashMap<BatchKey, Vec<u64>>,
    steps_served: u64,
}

impl ShardState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn n_sessions(&self) -> usize {
        self.slots.len()
    }

    /// Execute one request against this shard's sessions.
    pub fn handle(&mut self, req: Request) -> Response {
        match req {
            Request::Open { id, spec } => self.open(id, spec),
            Request::Step { id, x, c } => match self.step_session(id, &x, c) {
                Ok(y) => Response::Stepped { y },
                Err(e) => Response::error(e),
            },
            Request::StepMany { items } => Response::SteppedMany {
                ys: self.step_many(items),
            },
            Request::Predict { id, x } => match self.predict_session(id, &x) {
                Ok(y) => Response::Predicted { y },
                Err(e) => Response::error(e),
            },
            Request::Snapshot { id } => match self.snapshot_session(id) {
                Ok(state) => Response::Snapshotted { state },
                Err(e) => Response::error(e),
            },
            Request::Restore { id, state } => match Session::from_snapshot(&state) {
                Ok(session) => self.insert(id, session),
                Err(e) => Response::error(e),
            },
            Request::Close { id } => self.close(id),
            Request::Stats => Response::Stats(ShardStats {
                sessions: self.slots.len(),
                steps: self.steps_served,
                kinds: self.kind_counts(),
            }),
        }
    }

    /// Session counts per learner kind (as opened, i.e. the spec's kind
    /// tag — batched slots are always `columnar`-shaped but report the
    /// kind they were opened under).
    fn kind_counts(&self) -> Vec<(String, usize)> {
        let mut counts: BTreeMap<&'static str, usize> = BTreeMap::new();
        for slot in self.slots.values() {
            let kind = match slot {
                Slot::Scalar(session) => session.spec().learner.kind(),
                Slot::Batched(_, _, spec) => spec.learner.kind(),
            };
            *counts.entry(kind).or_insert(0) += 1;
        }
        counts
            .into_iter()
            .map(|(k, n)| (k.to_string(), n))
            .collect()
    }

    fn open(&mut self, id: u64, spec: SessionSpec) -> Response {
        match Session::open(spec) {
            Ok(session) => self.insert(id, session),
            Err(e) => Response::error(e),
        }
    }

    /// Place a (fresh or restored) session: batched store when the net's
    /// discovered capability allows, scalar otherwise.
    fn insert(&mut self, id: u64, session: Session) -> Response {
        if self.slots.contains_key(&id) {
            return Response::error(format!("session {id} already exists"));
        }
        let spec = session.spec().clone();
        if let Some(batch_spec) = session.columnar_batch_spec() {
            let key = batch_key(&batch_spec);
            let lane = match session.to_lane() {
                Ok(lane) => lane,
                Err(e) => return Response::error(e),
            };
            let batch = match self.batches.entry(key) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(e) => {
                    match ColumnarSessionBatch::from_lanes(batch_spec, &[]) {
                        Ok(b) => e.insert(b),
                        Err(msg) => return Response::error(msg),
                    }
                }
            };
            match batch.push_lane(lane) {
                Ok(idx) => {
                    self.lane_ids.entry(key).or_default().push(id);
                    debug_assert_eq!(self.lane_ids[&key].len(), idx + 1);
                    self.slots.insert(id, Slot::Batched(key, idx, spec));
                    Response::Opened { id }
                }
                Err(e) => Response::error(e),
            }
        } else {
            self.slots.insert(id, Slot::Scalar(Box::new(session)));
            Response::Opened { id }
        }
    }

    fn step_session(&mut self, id: u64, x: &[f32], c: f32) -> Result<f32, String> {
        let y = match self
            .slots
            .get_mut(&id)
            .ok_or_else(|| format!("no session {id}"))?
        {
            Slot::Scalar(session) => session.step(x, c)?,
            Slot::Batched(key, lane, spec) => {
                if x.len() != spec.n_inputs {
                    return Err(format!(
                        "session expects {} inputs, got {}",
                        spec.n_inputs,
                        x.len()
                    ));
                }
                self.batches
                    .get_mut(key)
                    .expect("batch exists for batched slot")
                    .step_one(*lane, x, c)
            }
        };
        self.steps_served += 1;
        Ok(y)
    }

    fn predict_session(&mut self, id: u64, x: &[f32]) -> Result<f32, String> {
        match self
            .slots
            .get_mut(&id)
            .ok_or_else(|| format!("no session {id}"))?
        {
            Slot::Scalar(session) => session.predict(x),
            Slot::Batched(key, lane, spec) => {
                if x.len() != spec.n_inputs {
                    return Err(format!(
                        "session expects {} inputs, got {}",
                        spec.n_inputs,
                        x.len()
                    ));
                }
                Ok(self
                    .batches
                    .get_mut(key)
                    .expect("batch exists for batched slot")
                    .predict_one(*lane, x))
            }
        }
    }

    /// Step many sessions. Groups that cover an entire SoA batch run
    /// through the fused [`ColumnarSessionBatch::step_all`]; everything
    /// else falls back to per-session stepping. Result order matches
    /// input order.
    fn step_many(&mut self, items: Vec<StepItem>) -> Vec<Result<f32, String>> {
        let n_items = items.len();
        let mut out: Vec<Option<Result<f32, String>>> = vec![None; n_items];
        // partition: which batch does each item belong to (if any)?
        let mut per_batch: HashMap<BatchKey, Vec<(usize, usize)>> = HashMap::new();
        for (pos, item) in items.iter().enumerate() {
            if let Some(Slot::Batched(key, lane, _)) = self.slots.get(&item.id) {
                per_batch.entry(*key).or_default().push((pos, *lane));
            }
        }
        for (key, members) in per_batch {
            let batch = self.batches.get_mut(&key).expect("batch exists");
            let bsz = batch.len();
            let n = batch.spec().n_inputs;
            // fused path only when every lane is covered exactly once and
            // every observation has the right width
            let full = members.len() == bsz && {
                let mut seen = vec![false; bsz];
                members.iter().all(|&(pos, lane)| {
                    let fresh = !seen[lane];
                    seen[lane] = true;
                    fresh && items[pos].x.len() == n
                })
            };
            if !full {
                continue; // handled by the scalar fallback below
            }
            let mut obs = vec![0.0f32; bsz * n];
            let mut cs = vec![0.0f32; bsz];
            for &(pos, lane) in &members {
                obs[lane * n..(lane + 1) * n].copy_from_slice(&items[pos].x);
                cs[lane] = items[pos].c;
            }
            let ys = batch.step_all(&obs, &cs).to_vec();
            for &(pos, lane) in &members {
                out[pos] = Some(Ok(ys[lane]));
            }
            self.steps_served += bsz as u64;
        }
        // scalar fallback for everything not answered by a fused pass
        for (pos, item) in items.into_iter().enumerate() {
            if out[pos].is_none() {
                out[pos] = Some(self.step_session(item.id, &item.x, item.c));
            }
        }
        out.into_iter().map(|r| r.expect("every item answered")).collect()
    }

    fn snapshot_session(&self, id: u64) -> Result<Json, String> {
        match self.slots.get(&id).ok_or_else(|| format!("no session {id}"))? {
            Slot::Scalar(session) => Ok(session.snapshot()),
            Slot::Batched(key, lane, spec) => {
                let batch = self.batches.get(key).expect("batch exists");
                let extracted = batch.extract_lane(*lane);
                let session =
                    Session::from_lane(spec.clone(), batch.spec(), &extracted)?;
                Ok(session.snapshot())
            }
        }
    }

    fn close(&mut self, id: u64) -> Response {
        match self.slots.remove(&id) {
            None => Response::error(format!("no session {id}")),
            Some(Slot::Scalar(session)) => Response::Closed {
                id,
                steps: session.steps(),
            },
            Some(Slot::Batched(key, lane, _)) => {
                let batch = self.batches.get_mut(&key).expect("batch exists");
                let steps = batch.session_steps(lane);
                if let Err(e) = batch.swap_remove_lane(lane) {
                    return Response::error(e);
                }
                // the last lane moved into `lane`: re-key that session
                let ids = self.lane_ids.get_mut(&key).expect("lane ids exist");
                let moved = ids.pop().expect("non-empty lane list");
                if moved != id {
                    ids[lane] = moved;
                    if let Some(Slot::Batched(_, l, _)) = self.slots.get_mut(&moved) {
                        *l = lane;
                    }
                }
                if batch.is_empty() {
                    self.batches.remove(&key);
                    self.lane_ids.remove(&key);
                }
                Response::Closed { id, steps }
            }
        }
    }
}

enum Job {
    Run(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// N shard worker threads plus the request router. The only shared state
/// is the id allocator — sessions live entirely inside their shard.
pub struct ShardPool {
    txs: Vec<mpsc::Sender<Job>>,
    joins: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl ShardPool {
    pub fn new(n_shards: usize) -> Self {
        let n = n_shards.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            txs.push(tx);
            joins.push(std::thread::spawn(move || {
                let mut state = ShardState::new();
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Run(req, reply) => {
                            // receiver may have hung up; that's fine
                            let _ = reply.send(state.handle(req));
                        }
                        Job::Shutdown => break,
                    }
                }
            }));
        }
        Self {
            txs,
            joins,
            next_id: AtomicU64::new(1),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.txs.len()
    }

    fn shard_of(&self, id: u64) -> usize {
        (id % self.txs.len() as u64) as usize
    }

    fn call_shard(&self, shard: usize, req: Request) -> Response {
        let (tx, rx) = mpsc::channel();
        if self.txs[shard].send(Job::Run(req, tx)).is_err() {
            return Response::error("shard worker is gone");
        }
        rx.recv()
            .unwrap_or_else(|_| Response::error("shard worker dropped the reply"))
    }

    /// Allocate an id and open a session on its shard.
    pub fn open(&self, spec: SessionSpec) -> Response {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.call_shard(self.shard_of(id), Request::Open { id, spec })
    }

    /// Allocate an id and restore a snapshot onto its shard.
    pub fn restore(&self, state: Json) -> Response {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.call_shard(self.shard_of(id), Request::Restore { id, state })
    }

    /// Route a single-session request to its owner.
    pub fn call(&self, req: Request) -> Response {
        match req.route_id() {
            Some(id) => self.call_shard(self.shard_of(id), req),
            None => Response::error("request has no routing id"),
        }
    }

    /// Scatter step items to their shards, step all shards *in
    /// parallel*, gather results back into input order. This is the
    /// aggregate hot path: one channel round-trip per shard per tick.
    pub fn step_batch(&self, items: Vec<StepItem>) -> Vec<Result<f32, String>> {
        let n_items = items.len();
        let mut per_shard: Vec<Vec<usize>> = vec![Vec::new(); self.txs.len()];
        let mut shard_items: Vec<Vec<StepItem>> = vec![Vec::new(); self.txs.len()];
        for (pos, item) in items.into_iter().enumerate() {
            let s = self.shard_of(item.id);
            per_shard[s].push(pos);
            shard_items[s].push(item);
        }
        let mut replies: Vec<Option<mpsc::Receiver<Response>>> =
            (0..self.txs.len()).map(|_| None).collect();
        for (s, batch) in shard_items.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            if self.txs[s]
                .send(Job::Run(Request::StepMany { items: batch }, tx))
                .is_ok()
            {
                replies[s] = Some(rx);
            }
        }
        let mut out: Vec<Result<f32, String>> =
            vec![Err("unanswered".into()); n_items];
        for (s, rx) in replies.into_iter().enumerate() {
            let Some(rx) = rx else {
                for &pos in &per_shard[s] {
                    out[pos] = Err("shard worker is gone".into());
                }
                continue;
            };
            match rx.recv() {
                Ok(Response::SteppedMany { ys }) => {
                    for (&pos, y) in per_shard[s].iter().zip(ys) {
                        out[pos] = y;
                    }
                }
                Ok(other) => {
                    let msg = match other {
                        Response::Error { message } => message,
                        _ => "unexpected shard reply".into(),
                    };
                    for &pos in &per_shard[s] {
                        out[pos] = Err(msg.clone());
                    }
                }
                Err(_) => {
                    for &pos in &per_shard[s] {
                        out[pos] = Err("shard worker dropped the reply".into());
                    }
                }
            }
        }
        out
    }

    /// Per-shard stats snapshots (sessions, steps served, per-kind
    /// session counts).
    pub fn stats(&self) -> Vec<ShardStats> {
        (0..self.txs.len())
            .map(|s| match self.call_shard(s, Request::Stats) {
                Response::Stats(st) => st,
                _ => ShardStats::default(),
            })
            .collect()
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Job::Shutdown);
        }
        for join in self.joins.drain(..) {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LearnerKind;
    use crate::learn::TdConfig;
    use crate::util::prng::Xoshiro256;

    fn spec(learner: LearnerKind, seed: u64) -> SessionSpec {
        SessionSpec {
            learner,
            n_inputs: 3,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            seed,
        }
    }

    fn open_ok(state: &mut ShardState, id: u64, s: SessionSpec) {
        match state.handle(Request::Open { id, spec: s }) {
            Response::Opened { id: got } => assert_eq!(got, id),
            other => panic!("open failed: {other:?}"),
        }
    }

    #[test]
    fn shard_state_full_lifecycle() {
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Columnar { d: 4 }, 0));
        open_ok(
            &mut st,
            2,
            spec(
                LearnerKind::Ccn {
                    total: 4,
                    per_stage: 2,
                    steps_per_stage: 1000,
                },
                1,
            ),
        );
        assert_eq!(st.n_sessions(), 2);
        let y = st.step_session(1, &[0.1, 0.2, 0.3], 0.5).unwrap();
        assert!(y.is_finite());
        assert!(st.step_session(9, &[0.0; 3], 0.0).is_err(), "unknown id");
        assert!(st.step_session(1, &[0.0; 2], 0.0).is_err(), "bad width");
        let snap = st.snapshot_session(1).unwrap();
        match st.handle(Request::Restore { id: 3, state: snap }) {
            Response::Opened { id } => assert_eq!(id, 3),
            other => panic!("restore failed: {other:?}"),
        }
        match st.handle(Request::Close { id: 1 }) {
            Response::Closed { id, steps } => {
                assert_eq!(id, 1);
                assert_eq!(steps, 1);
            }
            other => panic!("close failed: {other:?}"),
        }
        assert_eq!(st.n_sessions(), 2);
    }

    #[test]
    fn batched_and_scalar_routes_agree() {
        // same columnar spec through the batched store and through a
        // standalone scalar session: identical predictions.
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Columnar { d: 4 }, 42));
        let mut scalar = Session::open(spec(LearnerKind::Columnar { d: 4 }, 42)).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(0);
        for _ in 0..200 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            let y_shard = st.step_session(1, &x, c).unwrap();
            let y_scalar = scalar.step(&x, c).unwrap();
            assert_eq!(y_shard, y_scalar, "batched lane must equal scalar agent");
        }
    }

    #[test]
    fn step_many_fused_path_matches_fallback() {
        let mk = |st: &mut ShardState| {
            for id in 0..5u64 {
                open_ok(st, id + 1, spec(LearnerKind::Columnar { d: 3 }, id));
            }
        };
        let mut fused = ShardState::new();
        let mut fallback = ShardState::new();
        mk(&mut fused);
        mk(&mut fallback);
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..50 {
            let items: Vec<StepItem> = (0..5u64)
                .map(|id| StepItem {
                    id: id + 1,
                    x: (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                    c: rng.uniform(-0.5, 0.5),
                })
                .collect();
            // fused: all 5 lanes of the batch in one request
            let ys_fused = fused.step_many(items.clone());
            // fallback: one at a time (never a full batch in one call)
            let ys_one: Vec<Result<f32, String>> = items
                .iter()
                .map(|it| fallback.step_session(it.id, &it.x, it.c))
                .collect();
            for (a, b) in ys_fused.iter().zip(&ys_one) {
                assert_eq!(
                    a.as_ref().unwrap(),
                    b.as_ref().unwrap(),
                    "fused and scalar paths must agree"
                );
            }
        }
    }

    #[test]
    fn dense_baselines_serve_on_the_scalar_path() {
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Tbptt { d: 2, k: 5 }, 0));
        open_ok(&mut st, 2, spec(LearnerKind::Snap1 { d: 2 }, 1));
        open_ok(&mut st, 3, spec(LearnerKind::Columnar { d: 2 }, 2));
        assert_eq!(st.batches.len(), 1, "only the columnar session batches");
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..50 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            for id in 1..=3u64 {
                assert!(st.step_session(id, &x, 0.1).unwrap().is_finite());
            }
        }
        // snapshot/restore a dense session through the shard
        let snap = st.snapshot_session(1).unwrap();
        match st.handle(Request::Restore { id: 9, state: snap }) {
            Response::Opened { id } => assert_eq!(id, 9),
            other => panic!("tbptt restore failed: {other:?}"),
        }
        let kinds = st.kind_counts();
        assert_eq!(
            kinds,
            vec![
                ("columnar".to_string(), 1),
                ("snap1".to_string(), 1),
                ("tbptt".to_string(), 2),
            ]
        );
    }

    #[test]
    fn step_many_reports_per_item_errors() {
        let mut st = ShardState::new();
        open_ok(&mut st, 1, spec(LearnerKind::Columnar { d: 3 }, 0));
        let items = vec![
            StepItem {
                id: 1,
                x: vec![0.0; 3],
                c: 0.0,
            },
            StepItem {
                id: 77,
                x: vec![0.0; 3],
                c: 0.0,
            },
        ];
        let ys = st.step_many(items);
        assert!(ys[0].is_ok());
        assert!(ys[1].is_err());
    }

    #[test]
    fn close_rekeys_swapped_batch_lane() {
        let mut st = ShardState::new();
        for id in 1..=3u64 {
            open_ok(&mut st, id, spec(LearnerKind::Columnar { d: 2 }, id));
        }
        // twin of session 3 to verify integrity after the swap
        let mut twin = Session::open(spec(LearnerKind::Columnar { d: 2 }, 3)).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..30 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            for id in 1..=3u64 {
                let y = st.step_session(id, &x, 0.1).unwrap();
                if id == 3 {
                    assert_eq!(y, twin.step(&x, 0.1).unwrap());
                }
            }
        }
        // closing session 1 moves session 3 into lane 0
        st.handle(Request::Close { id: 1 });
        for _ in 0..30 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = st.step_session(3, &x, 0.1).unwrap();
            assert_eq!(y, twin.step(&x, 0.1).unwrap(), "lane re-key broke state");
        }
    }

    #[test]
    fn pool_routes_and_parallel_steps() {
        let pool = ShardPool::new(3);
        let mut ids = Vec::new();
        for s in 0..6u64 {
            match pool.open(spec(LearnerKind::Columnar { d: 3 }, s)) {
                Response::Opened { id } => ids.push(id),
                other => panic!("open failed: {other:?}"),
            }
        }
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..20 {
            let items: Vec<StepItem> = ids
                .iter()
                .map(|&id| StepItem {
                    id,
                    x: (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                    c: 0.1,
                })
                .collect();
            let ys = pool.step_batch(items);
            assert!(ys.iter().all(|y| y.is_ok()));
        }
        let stats = pool.stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(stats.iter().map(|s| s.sessions).sum::<usize>(), 6);
        assert_eq!(
            stats.iter().map(|s| s.steps).sum::<u64>(),
            6 * 20,
            "every step accounted"
        );
        // snapshot through the pool round-trips
        let snap = match pool.call(Request::Snapshot { id: ids[0] }) {
            Response::Snapshotted { state } => state,
            other => panic!("snapshot failed: {other:?}"),
        };
        match pool.restore(snap) {
            Response::Opened { .. } => {}
            other => panic!("restore failed: {other:?}"),
        }
    }
}
