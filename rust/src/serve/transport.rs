//! The network front end: a concurrent TCP / Unix-domain-socket listener
//! speaking the existing JSONL protocol ([`super::protocol`]).
//!
//! The stdio loop ([`super::Service::run_stdio`]) serves exactly one
//! client. This module puts a `std::net` listener in front of the same
//! [`super::Service`] so *N* clients drive the session machinery
//! concurrently — the system-level counterpart of the paper's
//! linear-compute-scaling claim: capacity grows with connections and
//! shards, never with gradient-quality compromises.
//!
//! # Design
//!
//! - [`ListenAddr`] parses `tcp://HOST:PORT` and `unix://PATH`.
//! - [`Server::bind`] owns the accept loop on its own thread; every
//!   accepted connection gets a **reader/writer thread pair**. The reader
//!   parses one request line at a time, executes it against the shared
//!   [`super::Service`] (a blocking shard round trip) and enqueues the
//!   reply on a **bounded** queue; the writer drains the queue to the
//!   socket. One request in flight per connection means replies come
//!   back strictly in request order, and all ops for a session id — from
//!   any connection — serialize through the session's owning shard, so
//!   per-session history stays replayable. Requests for *different*
//!   sessions from different connections interleave freely across
//!   shards. The reply queue holds at most `REPLY_QUEUE_CAP` entries: a
//!   client that stops draining replies blocks its own reader (TCP
//!   backpressure) instead of buffering server memory without limit.
//! - Connection lifecycle: a client EOF (or socket error) ends the
//!   reader; the writer drains every already-queued reply, shuts the
//!   socket down, and the connection deregisters. Sessions are owned by
//!   the service, not the connection — a dropped client loses nothing.
//! - `max_conns > 0` caps concurrent clients: a connection over the cap
//!   is answered with one JSONL error line and closed (counted under
//!   `refused`).
//! - `stats` replies over the transport carry an extra `"transport"`
//!   object tagging the asking connection and describing every live one:
//!   `{"conn":ID,"active_conns":..,"total_conns":..,"refused":..,
//!   "max_conns":..,"conns":[{"id":..,"peer":..,"requests":..,
//!   "errors":..,"err_decode":..,"err_oversize":..,"err_ghost_id":..,
//!   "err_io":..}]}`.
//! - Error taxonomy: `errors` totals request-level failures (any
//!   `ok:false` reply, over-long lines, bad UTF-8) exactly as before;
//!   the categories break it down — `err_decode` (malformed JSON, bad
//!   UTF-8, unknown/invalid ops), `err_oversize` (line over
//!   [`MAX_LINE_BYTES`]), `err_ghost_id` (ops addressed to a session id
//!   the service doesn't know). `err_io` counts socket-level read/write
//!   failures, which kill the connection rather than produce a reply and
//!   are therefore *not* part of `errors`. The same categories aggregate
//!   server-wide as `transport.err_*` counters in the `metrics` op, and
//!   transport stage latencies (`transport_read`/`transport_decode`/
//!   `transport_write`) land in the shared [`crate::obs::Registry`].
//! - [`Server::shutdown`] stops the accept loop, drains and joins every
//!   connection, then closes the service — flushing every resident
//!   session to the store. Killing the process instead is the crash
//!   path: only parked state survives, exactly as with the stdio loop.
//!
//! Blocking reads poll a stop flag via short read timeouts, so shutdown
//! never hangs on an idle client; writes carry a timeout so a stalled
//! client cannot wedge its writer thread forever. Non-UTF-8 request
//! lines get a structured error reply instead of killing the connection.
//!
//! See the `ccn serve --listen` flag and the module docs of
//! [`crate::serve`] for a wire-level quickstart.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::{Histogram, Registry};
use crate::util::fault::{self, FaultAction};
use crate::util::json::Json;

use super::protocol::{parse_wire_op, Response, WireOp};
use super::Service;

/// How often blocked readers/accepts wake to poll the stop flag.
pub(crate) const POLL_INTERVAL: Duration = Duration::from_millis(25);
/// A reply write slower than this counts as a dead client.
pub(crate) const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// Longest request line a connection may send. Snapshot envelopes are a
/// few KB, so 16MB is generous headroom — while a client that streams
/// bytes without ever sending a newline gets one error reply per capped
/// "line" instead of growing the read buffer until the process is
/// OOM-killed (which would lose every non-parked session).
pub(crate) const MAX_LINE_BYTES: usize = 16 << 20;
/// Replies that may queue between a connection's reader and writer
/// before the reader blocks. A client that sends requests faster than it
/// drains replies (or stops reading entirely) used to grow this queue
/// without bound — snapshot replies are megabytes, so a handful of slow
/// clients could OOM the server. Bounded, the reader stalls instead,
/// which stops consuming the client's requests and pushes the
/// backpressure onto its socket; a genuinely dead client is unwedged by
/// the writer's [`WRITE_TIMEOUT`], which drops the queue and errors the
/// reader out.
const REPLY_QUEUE_CAP: usize = 64;

/// A parsed `--listen` endpoint.
#[derive(Clone, Debug, PartialEq)]
pub enum ListenAddr {
    /// `tcp://HOST:PORT` (port 0 binds an ephemeral port).
    Tcp(String),
    /// `unix://PATH` — a filesystem socket, removed again on shutdown.
    Unix(PathBuf),
}

impl std::fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ListenAddr::Tcp(hostport) => write!(f, "tcp://{hostport}"),
            ListenAddr::Unix(path) => write!(f, "unix://{}", path.display()),
        }
    }
}

impl ListenAddr {
    pub fn parse(s: &str) -> Result<ListenAddr, String> {
        if let Some(rest) = s.strip_prefix("tcp://") {
            if rest.is_empty() || !rest.contains(':') {
                return Err(format!(
                    "listen: tcp address needs HOST:PORT, got '{rest}'"
                ));
            }
            Ok(ListenAddr::Tcp(rest.to_string()))
        } else if let Some(rest) = s.strip_prefix("unix://") {
            if rest.is_empty() {
                return Err("listen: unix address needs a path".into());
            }
            Ok(ListenAddr::Unix(PathBuf::from(rest)))
        } else {
            Err(format!(
                "listen: expected tcp://HOST:PORT or unix://PATH, got '{s}'"
            ))
        }
    }
}

/// One connection, TCP or UDS, behind a uniform surface — accepted by
/// [`Listener`], or dialed out via [`Stream::connect`] (the cluster
/// tier's client side).
pub(crate) enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    /// Dial a serve endpoint. The timeout bounds the TCP connect (Unix
    /// sockets connect or fail immediately); read/write timeouts are the
    /// caller's to set afterwards.
    pub(crate) fn connect(
        addr: &ListenAddr,
        timeout: Duration,
    ) -> std::io::Result<Stream> {
        match addr {
            ListenAddr::Tcp(hostport) => {
                use std::net::ToSocketAddrs;
                let mut last: Option<std::io::Error> = None;
                for sa in hostport.to_socket_addrs()? {
                    match TcpStream::connect_timeout(&sa, timeout) {
                        Ok(s) => return Ok(Stream::Tcp(s)),
                        Err(e) => last = Some(e),
                    }
                }
                Err(last.unwrap_or_else(|| {
                    std::io::Error::new(
                        ErrorKind::AddrNotAvailable,
                        format!("{hostport}: no addresses resolved"),
                    )
                }))
            }
            ListenAddr::Unix(path) => {
                UnixStream::connect(path).map(Stream::Unix)
            }
        }
    }

    pub(crate) fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    pub(crate) fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    pub(crate) fn set_read_timeout(
        &self,
        d: Option<Duration>,
    ) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }

    pub(crate) fn set_write_timeout(
        &self,
        d: Option<Duration>,
    ) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(d),
            Stream::Unix(s) => s.set_write_timeout(d),
        }
    }

    pub(crate) fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            Stream::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    pub(crate) fn peer(&self) -> String {
        match self {
            Stream::Tcp(s) => s
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:?".into()),
            Stream::Unix(_) => "unix".into(),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Exclusive claim on a unix socket *path*, taken before any
/// stale-socket unlinking. Without it, two servers starting on the same
/// path can both find the socket unanswering, both conclude "stale", and
/// unlink each other's fresh bind — the classic check-then-act race.
/// Same pid-file pattern as the store's `LOCK` (and the same best-effort
/// caveat): `<path>.lock` holds the owner's pid; a live foreign pid
/// refuses the bind, a dead one is taken over. The file is created with
/// `create_new` (O_EXCL), so exactly one of two simultaneous starters
/// wins the claim — the loser re-reads and either refuses (live owner)
/// or retries once (the winner died mid-start).
pub(crate) struct SocketLock {
    path: PathBuf,
}

impl SocketLock {
    fn acquire(sock: &std::path::Path) -> Result<SocketLock, String> {
        let path = PathBuf::from(format!("{}.lock", sock.display()));
        let me = std::process::id();
        for attempt in 0..2 {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    if let Err(e) = f.write_all(me.to_string().as_bytes()) {
                        let _ = std::fs::remove_file(&path);
                        return Err(format!(
                            "listen: write lock {}: {e}",
                            path.display()
                        ));
                    }
                    return Ok(SocketLock { path });
                }
                Err(e) if e.kind() == ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&path)
                        .ok()
                        .and_then(|s| s.trim().parse::<u32>().ok());
                    if let Some(pid) = holder {
                        if pid != me
                            && std::path::Path::new(&format!("/proc/{pid}"))
                                .exists()
                        {
                            return Err(format!(
                                "listen: {} is locked by live process {pid}",
                                sock.display()
                            ));
                        }
                    }
                    // stale (dead/unparseable holder): unlink and retry
                    // create_new once — losing that race means a live
                    // starter just won, which the re-read above catches
                    if attempt == 0 {
                        let _ = std::fs::remove_file(&path);
                    }
                }
                Err(e) => {
                    return Err(format!(
                        "listen: lock {}: {e}",
                        path.display()
                    ))
                }
            }
        }
        Err(format!(
            "listen: lock {}: lost the takeover race",
            path.display()
        ))
    }
}

impl Drop for SocketLock {
    fn drop(&mut self) {
        // release only if the file still names us — never delete a lock
        // a faster starter took over after our crash window
        if let Ok(prev) = std::fs::read_to_string(&self.path) {
            if prev.trim() == std::process::id().to_string() {
                let _ = std::fs::remove_file(&self.path);
            }
        }
    }
}

pub(crate) enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    /// Bind the endpoint. For unix sockets the returned [`SocketLock`]
    /// guards the *path* (hold it as long as the listener lives — it is
    /// what makes stale-socket takeover safe against a simultaneous
    /// starter); TCP binds return `None`.
    pub(crate) fn bind(
        addr: &ListenAddr,
    ) -> Result<(Listener, String, Option<SocketLock>), String> {
        match addr {
            ListenAddr::Tcp(hostport) => {
                let l = TcpListener::bind(hostport)
                    .map_err(|e| format!("listen: bind tcp://{hostport}: {e}"))?;
                let local = l
                    .local_addr()
                    .map(|a| format!("tcp://{a}"))
                    .unwrap_or_else(|_| format!("tcp://{hostport}"));
                Ok((Listener::Tcp(l), local, None))
            }
            ListenAddr::Unix(path) => {
                // claim the path before any liveness probing or
                // unlinking: holding the lock makes check-then-unlink
                // atomic with respect to other starters
                let lock = SocketLock::acquire(path)?;
                let l = match UnixListener::bind(path) {
                    Ok(l) => l,
                    Err(e) if e.kind() == ErrorKind::AddrInUse => {
                        // A socket file from an earlier run. If nobody
                        // accepts on it the server crashed without
                        // cleanup: remove the stale file and rebind. If
                        // someone answers, a live server owns it.
                        if UnixStream::connect(path).is_ok() {
                            return Err(format!(
                                "listen: {} is owned by a live server",
                                path.display()
                            ));
                        }
                        std::fs::remove_file(path).map_err(|e| {
                            format!(
                                "listen: remove stale socket {}: {e}",
                                path.display()
                            )
                        })?;
                        UnixListener::bind(path).map_err(|e| {
                            format!("listen: bind unix://{}: {e}", path.display())
                        })?
                    }
                    Err(e) => {
                        return Err(format!(
                            "listen: bind unix://{}: {e}",
                            path.display()
                        ))
                    }
                };
                Ok((
                    Listener::Unix(l),
                    format!("unix://{}", path.display()),
                    Some(lock),
                ))
            }
        }
    }

    pub(crate) fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    pub(crate) fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }
}

/// Per-connection counters, visible through the `stats` op. See the
/// module docs for the error taxonomy (`errors` is the request-level
/// total; the `err_*` categories break it down, except `err_io` which
/// counts reply-less socket failures).
struct ConnStats {
    id: u64,
    peer: String,
    requests: AtomicU64,
    errors: AtomicU64,
    err_decode: AtomicU64,
    err_oversize: AtomicU64,
    err_ghost_id: AtomicU64,
    err_io: AtomicU64,
}

impl ConnStats {
    fn new(id: u64, peer: String) -> ConnStats {
        ConnStats {
            id,
            peer,
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            err_decode: AtomicU64::new(0),
            err_oversize: AtomicU64::new(0),
            err_ghost_id: AtomicU64::new(0),
            err_io: AtomicU64::new(0),
        }
    }
}

/// Pre-resolved registry handles for the transport stage timers and the
/// server-wide error-category counters. Resolved once at bind, cloned
/// per connection — the per-request path never touches the registry
/// lock.
#[derive(Clone)]
struct TransportObs {
    read: Arc<Histogram>,
    decode: Arc<Histogram>,
    write: Arc<Histogram>,
    err_decode: Arc<AtomicU64>,
    err_oversize: Arc<AtomicU64>,
    err_ghost_id: Arc<AtomicU64>,
    err_io: Arc<AtomicU64>,
}

impl TransportObs {
    fn new(registry: &Registry) -> TransportObs {
        TransportObs {
            read: registry.histogram("stage.transport_read"),
            decode: registry.histogram("stage.transport_decode"),
            write: registry.histogram("stage.transport_write"),
            err_decode: registry.counter("transport.err_decode"),
            err_oversize: registry.counter("transport.err_oversize"),
            err_ghost_id: registry.counter("transport.err_ghost_id"),
            err_io: registry.counter("transport.err_io"),
        }
    }
}

/// State shared by the accept loop and every connection thread.
struct Shared {
    stop: AtomicBool,
    conns: Mutex<BTreeMap<u64, Arc<ConnStats>>>,
    total_conns: AtomicU64,
    refused: AtomicU64,
    max_conns: usize,
}

/// A live listener serving the JSONL protocol to concurrent clients.
///
/// Constructed by [`Server::bind`]; torn down by [`Server::shutdown`]
/// (which is also the graceful store flush — do not skip it unless a
/// crash is exactly what you want to simulate).
pub struct Server {
    service: Arc<Service>,
    shared: Arc<Shared>,
    accept_join: Option<JoinHandle<()>>,
    conn_joins: Arc<Mutex<Vec<JoinHandle<()>>>>,
    local: String,
    unix_path: Option<PathBuf>,
    /// Claim on the unix socket *path* (see [`SocketLock`]); released on
    /// drop, strictly after `shutdown` removes the socket file itself.
    sock_lock: Option<SocketLock>,
}

impl Server {
    /// Bind the endpoint and start accepting. `max_conns == 0` means
    /// unlimited.
    pub fn bind(
        service: Service,
        addr: &ListenAddr,
        max_conns: usize,
    ) -> Result<Server, String> {
        let (listener, local, sock_lock) = Listener::bind(addr)?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("listen: set nonblocking: {e}"))?;
        let obs = TransportObs::new(service.registry());
        let service = Arc::new(service);
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            conns: Mutex::new(BTreeMap::new()),
            total_conns: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            max_conns,
        });
        let conn_joins = Arc::new(Mutex::new(Vec::new()));
        let accept_join = {
            let service = Arc::clone(&service);
            let shared = Arc::clone(&shared);
            let conn_joins = Arc::clone(&conn_joins);
            std::thread::spawn(move || {
                run_accept(listener, service, shared, conn_joins, obs)
            })
        };
        Ok(Server {
            service,
            shared,
            accept_join: Some(accept_join),
            conn_joins,
            local,
            unix_path: match addr {
                ListenAddr::Unix(p) => Some(p.clone()),
                ListenAddr::Tcp(_) => None,
            },
            sock_lock,
        })
    }

    /// The bound endpoint, e.g. `tcp://127.0.0.1:40123` — with the real
    /// port when the request was for port 0.
    pub fn local_addr(&self) -> &str {
        &self.local
    }

    /// Currently connected clients.
    pub fn active_conns(&self) -> usize {
        self.shared.conns.lock().map(|c| c.len()).unwrap_or(0)
    }

    /// The service behind the listener (stats introspection).
    pub fn service(&self) -> &Service {
        &self.service
    }

    /// Graceful shutdown: stop accepting, drain and join every
    /// connection (queued replies are still delivered), remove the unix
    /// socket file, then close the service — flushing every resident
    /// session to the store. Returns the number flushed.
    pub fn shutdown(mut self) -> Result<usize, String> {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(join) = self.accept_join.take() {
            let _ = join.join();
        }
        let joins: Vec<JoinHandle<()>> = match self.conn_joins.lock() {
            Ok(mut j) => std::mem::take(&mut *j),
            Err(_) => Vec::new(),
        };
        for join in joins {
            let _ = join.join();
        }
        if let Some(path) = &self.unix_path {
            let _ = std::fs::remove_file(path);
        }
        // socket gone, now the path claim may go too
        drop(self.sock_lock.take());
        let mut service = Arc::try_unwrap(self.service)
            .map_err(|_| "shutdown: a connection thread still holds the service")?;
        service.close()
    }
}

fn run_accept(
    listener: Listener,
    service: Arc<Service>,
    shared: Arc<Shared>,
    conn_joins: Arc<Mutex<Vec<JoinHandle<()>>>>,
    obs: TransportObs,
) {
    let mut next_conn = 1u64;
    while !shared.stop.load(Ordering::Relaxed) {
        let stream = match listener.accept() {
            Ok(s) => s,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut
                ) =>
            {
                std::thread::sleep(POLL_INTERVAL);
                continue;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                // transient accept failure (EMFILE, aborted handshake):
                // back off instead of spinning or dying
                std::thread::sleep(POLL_INTERVAL);
                continue;
            }
        };
        // accepted sockets may inherit the listener's nonblocking mode on
        // some platforms; make them blocking-with-timeout explicitly
        let _ = stream.set_nonblocking(false);
        let active = shared.conns.lock().map(|c| c.len()).unwrap_or(usize::MAX);
        if shared.max_conns > 0 && active >= shared.max_conns {
            shared.refused.fetch_add(1, Ordering::Relaxed);
            let mut s = stream;
            let _ = s.set_write_timeout(Some(WRITE_TIMEOUT));
            let reply = Response::error(format!(
                "server is at --max-conns ({})",
                shared.max_conns
            ))
            .to_json()
            .dump();
            let _ = writeln!(s, "{reply}");
            let _ = s.flush();
            s.shutdown();
            continue;
        }
        let id = next_conn;
        next_conn += 1;
        shared.total_conns.fetch_add(1, Ordering::Relaxed);
        let stats = Arc::new(ConnStats::new(id, stream.peer()));
        let write_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => {
                stream.shutdown();
                continue;
            }
        };
        if let Ok(mut conns) = shared.conns.lock() {
            conns.insert(id, Arc::clone(&stats));
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel::<String>(REPLY_QUEUE_CAP);
        let writer = {
            let obs = obs.clone();
            let stats = Arc::clone(&stats);
            std::thread::spawn(move || run_writer(write_half, reply_rx, obs, stats))
        };
        let reader = {
            let service = Arc::clone(&service);
            let shared = Arc::clone(&shared);
            let obs = obs.clone();
            std::thread::spawn(move || {
                run_reader(stream, service, Arc::clone(&shared), stats, reply_tx, obs);
                if let Ok(mut conns) = shared.conns.lock() {
                    conns.remove(&id);
                }
            })
        };
        if let Ok(mut joins) = conn_joins.lock() {
            // reap handles of connections that already finished, so a
            // long-lived server churning short-lived clients doesn't
            // accumulate one dead JoinHandle pair per connection forever
            joins.retain(|j| !j.is_finished());
            joins.push(reader);
            joins.push(writer);
        }
    }
}

/// Outcome of reading one request line off a connection.
pub(crate) enum LineRead {
    /// A line (or a final unterminated line at EOF) is in the buffer.
    Line,
    /// The line crossed [`MAX_LINE_BYTES`]; its excess was discarded up
    /// to (and including) the terminating newline. The buffer is empty.
    TooLong,
    /// Clean end of stream with nothing buffered (or server stop).
    Eof,
}

/// Read one `\n`-terminated line into `buf`, riding out read timeouts
/// (which exist only so the stop flag gets polled) and capping the
/// buffered length at `max` — an over-long line is *drained*, not
/// stored, so the connection stays usable and memory stays bounded.
///
/// `read_hist` clocks the `transport_read` stage: from the first byte
/// of the line being available to the line being complete — idle wait
/// for a client to say anything is not read latency and is excluded.
pub(crate) fn read_line_bytes(
    reader: &mut BufReader<Stream>,
    buf: &mut Vec<u8>,
    stop: &AtomicBool,
    max: usize,
    read_hist: &Histogram,
) -> std::io::Result<LineRead> {
    let mut over = false;
    let mut started: Option<Instant> = None;
    let clock = |s: &Option<Instant>| {
        if let Some(t) = s {
            read_hist.record_duration(t.elapsed());
        }
    };
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) => c,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock
                        | ErrorKind::TimedOut
                        | ErrorKind::Interrupted
                ) =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(LineRead::Eof);
                }
                continue;
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            // EOF: flush a final unterminated line if one is buffered
            return Ok(if over {
                clock(&started);
                LineRead::TooLong
            } else if buf.is_empty() {
                LineRead::Eof
            } else {
                clock(&started);
                LineRead::Line
            });
        }
        if started.is_none() {
            started = Some(Instant::now());
        }
        let newline = chunk.iter().position(|&b| b == b'\n');
        let take = newline.map_or(chunk.len(), |p| p + 1);
        if !over {
            if buf.len() + take > max {
                over = true;
                buf.clear(); // stop storing; keep draining to the newline
            } else {
                buf.extend_from_slice(&chunk[..take]);
            }
        }
        reader.consume(take);
        if newline.is_some() {
            clock(&started);
            return Ok(if over { LineRead::TooLong } else { LineRead::Line });
        }
    }
}

fn run_reader(
    stream: Stream,
    service: Arc<Service>,
    shared: Arc<Shared>,
    stats: Arc<ConnStats>,
    reply_tx: mpsc::SyncSender<String>,
    obs: TransportObs,
) {
    let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            break;
        }
        buf.clear();
        match read_line_bytes(
            &mut reader,
            &mut buf,
            &shared.stop,
            MAX_LINE_BYTES,
            &obs.read,
        ) {
            Ok(LineRead::Line) => {}
            Ok(LineRead::TooLong) => {
                stats.requests.fetch_add(1, Ordering::Relaxed);
                stats.errors.fetch_add(1, Ordering::Relaxed);
                stats.err_oversize.fetch_add(1, Ordering::Relaxed);
                obs.err_oversize.fetch_add(1, Ordering::Relaxed);
                let reply = Response::error(format!(
                    "request line exceeds {MAX_LINE_BYTES} bytes"
                ))
                .to_json()
                .dump();
                if reply_tx.send(reply).is_err() {
                    break;
                }
                continue;
            }
            Ok(LineRead::Eof) => break,
            Err(_) => {
                stats.err_io.fetch_add(1, Ordering::Relaxed);
                obs.err_io.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        // chaos hook: a complete request line has arrived but nothing
        // has executed yet — drop the connection, stall, cut the line
        // short, or deliver it twice (duplicate delivery on the wire)
        let mut exec_twice = false;
        match fault::hit("transport.read") {
            Some(FaultAction::Drop) => {
                stats.err_io.fetch_add(1, Ordering::Relaxed);
                obs.err_io.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Some(FaultAction::Delay(ms)) => fault::sleep_ms(ms),
            Some(FaultAction::Truncate) => {
                let keep = buf.len() / 2;
                buf.truncate(keep);
            }
            Some(FaultAction::Dup) => exec_twice = true,
            None => {}
        }
        let reply = match std::str::from_utf8(&buf) {
            Err(_) => {
                stats.requests.fetch_add(1, Ordering::Relaxed);
                stats.errors.fetch_add(1, Ordering::Relaxed);
                stats.err_decode.fetch_add(1, Ordering::Relaxed);
                obs.err_decode.fetch_add(1, Ordering::Relaxed);
                Response::error("request line is not valid utf-8")
                    .to_json()
                    .dump()
            }
            Ok(text) => {
                let line = text.trim();
                if line.is_empty() {
                    continue;
                }
                stats.requests.fetch_add(1, Ordering::Relaxed);
                handle_request(&service, &shared, &stats, &obs, line)
            }
        };
        if reply_tx.send(reply).is_err() {
            break; // writer is gone (client stopped reading)
        }
        if exec_twice {
            if let Ok(text) = std::str::from_utf8(&buf) {
                let line = text.trim();
                if !line.is_empty() {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let reply =
                        handle_request(&service, &shared, &stats, &obs, line);
                    if reply_tx.send(reply).is_err() {
                        break;
                    }
                }
            }
        }
    }
    // dropping reply_tx lets the writer drain queued replies and exit
}

fn run_writer(
    stream: Stream,
    replies: mpsc::Receiver<String>,
    obs: TransportObs,
    stats: Arc<ConnStats>,
) {
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut out = BufWriter::new(stream);
    for reply in replies {
        // chaos hook: the reply is computed but not yet on the wire —
        // lose it (the client must time out), stall it, send it twice,
        // or tear the line in half and die
        match fault::hit("transport.write") {
            Some(FaultAction::Drop) => continue,
            Some(FaultAction::Delay(ms)) => fault::sleep_ms(ms),
            Some(FaultAction::Truncate) => {
                let half = &reply.as_bytes()[..reply.len() / 2];
                let _ = out.write_all(half).and_then(|()| out.flush());
                break;
            }
            Some(FaultAction::Dup) => {
                let _ = writeln!(out, "{reply}");
            }
            None => {}
        }
        let t = Instant::now();
        if writeln!(out, "{reply}")
            .and_then(|()| out.flush())
            .is_err()
        {
            stats.err_io.fetch_add(1, Ordering::Relaxed);
            obs.err_io.fetch_add(1, Ordering::Relaxed);
            break;
        }
        obs.write.record_duration(t.elapsed());
    }
    // drain done (or client dead): half-close so the client sees EOF
    if let Ok(inner) = out.into_inner() {
        inner.shutdown();
    }
}

/// Execute one request line; `stats` replies grow the `"transport"` tag.
fn handle_request(
    service: &Service,
    shared: &Shared,
    me: &ConnStats,
    obs: &TransportObs,
    line: &str,
) -> String {
    // decode stage: raw bytes -> validated WireOp, failures included
    let t = Instant::now();
    let parsed = Json::parse(line)
        .map_err(|e| format!("bad json: {e}"))
        .and_then(|v| parse_wire_op(&v));
    obs.decode.record_duration(t.elapsed());
    let reply = match parsed {
        Err(e) => {
            me.err_decode.fetch_add(1, Ordering::Relaxed);
            obs.err_decode.fetch_add(1, Ordering::Relaxed);
            Response::error(e).to_json()
        }
        Ok(op) => {
            let is_stats = matches!(op, WireOp::Stats);
            let reply = service.handle_op(op);
            if reply.get("ok") == Some(&Json::Bool(false)) {
                // "no session <id>" is the service's stable phrasing for
                // ops addressed to ids it doesn't know (ghost ids)
                let ghost = reply
                    .get("error")
                    .and_then(|e| e.as_str())
                    .is_some_and(|msg| msg.contains("no session"));
                if ghost {
                    me.err_ghost_id.fetch_add(1, Ordering::Relaxed);
                    obs.err_ghost_id.fetch_add(1, Ordering::Relaxed);
                }
            }
            if is_stats {
                attach_transport(reply, shared, me)
            } else {
                reply
            }
        }
    };
    if reply.get("ok") == Some(&Json::Bool(false)) {
        me.errors.fetch_add(1, Ordering::Relaxed);
    }
    reply.dump()
}

fn attach_transport(reply: Json, shared: &Shared, me: &ConnStats) -> Json {
    let (active, conn_list) = match shared.conns.lock() {
        Ok(conns) => (
            conns.len(),
            conns
                .values()
                .map(|c| {
                    Json::obj(vec![
                        ("id", Json::Num(c.id as f64)),
                        ("peer", Json::Str(c.peer.clone())),
                        (
                            "requests",
                            Json::Num(c.requests.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "errors",
                            Json::Num(c.errors.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "err_decode",
                            Json::Num(c.err_decode.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "err_oversize",
                            Json::Num(c.err_oversize.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "err_ghost_id",
                            Json::Num(c.err_ghost_id.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "err_io",
                            Json::Num(c.err_io.load(Ordering::Relaxed) as f64),
                        ),
                    ])
                })
                .collect::<Vec<Json>>(),
        ),
        Err(_) => (0, Vec::new()),
    };
    match reply {
        Json::Obj(mut o) => {
            o.insert(
                "transport".into(),
                Json::obj(vec![
                    ("conn", Json::Num(me.id as f64)),
                    ("active_conns", Json::Num(active as f64)),
                    (
                        "total_conns",
                        Json::Num(shared.total_conns.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "refused",
                        Json::Num(shared.refused.load(Ordering::Relaxed) as f64),
                    ),
                    ("max_conns", Json::Num(shared.max_conns as f64)),
                    ("conns", Json::Arr(conn_list)),
                ]),
            );
            Json::Obj(o)
        }
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_addr_parses_and_rejects() {
        assert_eq!(
            ListenAddr::parse("tcp://127.0.0.1:7777").unwrap(),
            ListenAddr::Tcp("127.0.0.1:7777".into())
        );
        assert_eq!(
            ListenAddr::parse("unix:///tmp/ccn.sock").unwrap(),
            ListenAddr::Unix(PathBuf::from("/tmp/ccn.sock"))
        );
        assert!(ListenAddr::parse("tcp://").is_err());
        assert!(ListenAddr::parse("tcp://nohost").is_err());
        assert!(ListenAddr::parse("unix://").is_err());
        assert!(ListenAddr::parse("http://x:1").is_err());
        assert!(ListenAddr::parse("127.0.0.1:7777").is_err());
    }

    #[test]
    fn bind_reports_the_real_port_and_shuts_down_cleanly() {
        let server = Server::bind(
            Service::new(1),
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            0,
        )
        .unwrap();
        let local = server.local_addr().to_string();
        assert!(local.starts_with("tcp://127.0.0.1:"), "{local}");
        assert!(!local.ends_with(":0"), "port 0 must resolve: {local}");
        assert_eq!(server.active_conns(), 0);
        // storeless close flushes nothing but must join everything
        assert_eq!(server.shutdown().unwrap(), 0);
    }

    #[test]
    fn stale_unix_socket_is_replaced_live_one_refused() {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let path = std::env::temp_dir()
            .join(format!("ccn-stale-{}-{nanos}.sock", std::process::id()));
        // a socket file nobody listens on (simulated crash leftover)
        drop(UnixListener::bind(&path).unwrap());
        assert!(path.exists());
        let addr = ListenAddr::Unix(path.clone());
        let server = Server::bind(Service::new(1), &addr, 0).unwrap();
        // while this server is live, a second bind must refuse
        let err = Server::bind(Service::new(1), &addr, 0).unwrap_err();
        assert!(err.contains("live server"), "{err}");
        server.shutdown().unwrap();
        assert!(!path.exists(), "shutdown removes the socket file");
        let lock = PathBuf::from(format!("{}.lock", path.display()));
        assert!(!lock.exists(), "shutdown releases the path lock");
    }

    #[test]
    fn socket_path_lock_refuses_live_foreign_owner_takes_over_stale() {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let path = std::env::temp_dir()
            .join(format!("ccn-lock-{}-{nanos}.sock", std::process::id()));
        let lock_path = PathBuf::from(format!("{}.lock", path.display()));
        // a live foreign pid holds the path: refuse before touching the
        // socket file at all (pid 1 always exists)
        std::fs::write(&lock_path, "1").unwrap();
        drop(UnixListener::bind(&path).unwrap()); // stale-looking socket
        let addr = ListenAddr::Unix(path.clone());
        let err = Server::bind(Service::new(1), &addr, 0).unwrap_err();
        assert!(err.contains("locked by live process 1"), "{err}");
        assert!(
            path.exists(),
            "a refused bind must not unlink the contested socket"
        );
        // a stale (dead) holder is taken over: crash recovery stays
        // hands-off even with both leftover files on disk
        std::fs::write(&lock_path, "999999999").unwrap();
        let server = Server::bind(Service::new(1), &addr, 0).unwrap();
        assert_eq!(
            std::fs::read_to_string(&lock_path).unwrap().trim(),
            std::process::id().to_string(),
            "takeover rewrites the lock to the new owner"
        );
        server.shutdown().unwrap();
        assert!(!path.exists() && !lock_path.exists(), "clean teardown");
    }

    #[test]
    fn ephemeral_streams_connect_both_kinds() {
        // tcp round trip through Stream::connect
        let server = Server::bind(
            Service::new(1),
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            0,
        )
        .unwrap();
        let addr = ListenAddr::parse(server.local_addr()).unwrap();
        let mut s = Stream::connect(&addr, Duration::from_secs(5)).unwrap();
        writeln!(s, "{}", r#"{"op":"ping"}"#).unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains(r#""pong":true"#), "{line}");
        s.shutdown();
        server.shutdown().unwrap();

        // and the same over a unix socket
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let path = std::env::temp_dir()
            .join(format!("ccn-dial-{}-{nanos}.sock", std::process::id()));
        let addr = ListenAddr::Unix(path.clone());
        let server = Server::bind(Service::new(1), &addr, 0).unwrap();
        let mut s = Stream::connect(&addr, Duration::from_secs(5)).unwrap();
        writeln!(s, "{}", r#"{"op":"ping"}"#).unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains(r#""pong":true"#), "{line}");
        s.shutdown();
        server.shutdown().unwrap();
    }
}
