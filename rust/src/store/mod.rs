//! `store` — the durable session tier under the serve subsystem.
//!
//! The serve layer ([`crate::serve`]) can snapshot any net family into
//! the versioned `{"v":2,"kind":...}` envelope; this module gives those
//! envelopes a disk home so sessions survive memory pressure and process
//! restarts. The paper's learners keep *exact* RTRL gradients in O(1)
//! memory per step — cheap enough that a session's complete state is a
//! few kilobytes — so unlike truncated/approximate estimators the service
//! never has to trade gradient quality for capacity: it parks cold
//! sessions instead.
//!
//! Layers:
//!
//! - [`segment`]: the on-disk format — newline-delimited JSON records
//!   (`park` snapshots and `del` tombstones) in numbered append-only
//!   segment files, torn-tail tolerant.
//! - [`SessionStore`]: one directory = one store — in-memory index
//!   (id -> segment/offset/length/kind), synced appends, and
//!   append-compact garbage collection committed by atomic
//!   write-then-rename.
//! - [`StoreConfig`]: how the serve layer mounts the tier — a root
//!   directory (each shard claims `shard-<k>/` under it) and a
//!   per-shard resident capacity.
//!
//! # Lifecycle with the serve layer
//!
//! Each shard owns a `SessionStore` and a resident-session LRU. When a
//! shard exceeds its resident capacity it evicts the coldest session:
//! snapshot -> [`SessionStore::park`] -> drop the in-memory slot
//! (including the session's lane in the SoA columnar batch). Any
//! subsequent op addressed to a parked id transparently rehydrates it
//! through [`crate::nets::NetRegistry`]. On graceful shutdown every
//! resident session is flushed; on boot [`SessionStore::scan`] (via the
//! rebuilt index) resumes every parked session lazily. See
//! [`crate::serve`] for the `park`/`warm` wire ops and the protocol
//! example.
//!
//! Crash model: a `park` is acknowledged only after the record is synced,
//! so an acknowledged snapshot survives `kill -9`. A torn final append is
//! truncated on the next open; an interrupted compaction leaves either
//! the old segments or the complete new one, never a mix.

pub mod segment;
pub mod session_store;

pub use session_store::SessionStore;

use std::path::PathBuf;

/// Mount configuration for the durable tier, carried from the CLI
/// (`ccn serve --store-dir DIR --resident-cap K`) into the shard pool.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Root directory; shard `k` stores under `<dir>/shard-<k>/`.
    pub dir: PathBuf,
    /// Resident sessions each shard keeps in memory before evicting its
    /// least-recently-used to disk. `0` means unlimited (the store still
    /// serves explicit `park` ops and shutdown flushes).
    pub resident_cap: usize,
}

impl StoreConfig {
    pub fn new(dir: impl Into<PathBuf>, resident_cap: usize) -> StoreConfig {
        StoreConfig {
            dir: dir.into(),
            resident_cap,
        }
    }

    /// The per-shard store directory.
    pub fn shard_dir(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("shard-{shard}"))
    }
}
