//! `store` — the durable session tier under the serve subsystem.
//!
//! The serve layer ([`crate::serve`]) can snapshot any net family into
//! the versioned `{"v":2,"kind":...}` envelope; this module gives those
//! envelopes a disk home so sessions survive memory pressure and process
//! restarts. The paper's learners keep *exact* RTRL gradients in O(1)
//! memory per step — cheap enough that a session's complete state is a
//! few kilobytes — so unlike truncated/approximate estimators the service
//! never has to trade gradient quality for capacity: it parks cold
//! sessions instead.
//!
//! Layers:
//!
//! - [`segment`]: the on-disk format — newline-delimited JSON records
//!   (`park` snapshots and `del` tombstones) in numbered append-only
//!   segment files, torn-tail tolerant.
//! - [`SessionStore`]: one directory = one store — in-memory index
//!   (id -> segment/offset/length/kind), synced appends, and
//!   append-compact garbage collection committed by atomic
//!   write-then-rename.
//! - [`StoreConfig`]: how the serve layer mounts the tier — a root
//!   directory (each shard claims `shard-<k>/` under it) and a
//!   per-shard resident capacity.
//! - [`IdWatermark`]: a durable, chunk-persisted floor for the pool-wide
//!   session-id allocator (`<dir>/next-id`), so ids of sessions that
//!   were never parked cannot be reused after a crash.
//!
//! # Lifecycle with the serve layer
//!
//! Each shard owns a `SessionStore` and a resident-session LRU. When a
//! shard exceeds its resident capacity it evicts the coldest session:
//! snapshot -> [`SessionStore::park`] -> drop the in-memory slot
//! (including the session's lane in the SoA columnar batch). Any
//! subsequent op addressed to a parked id transparently rehydrates it
//! through [`crate::nets::NetRegistry`]. On graceful shutdown every
//! resident session is flushed; on boot [`SessionStore::scan`] (via the
//! rebuilt index) resumes every parked session lazily. See
//! [`crate::serve`] for the `park`/`warm` wire ops and the protocol
//! example.
//!
//! Crash model: a `park` is acknowledged only after the record is synced,
//! so an acknowledged snapshot survives `kill -9`. A torn final append is
//! truncated on the next open; an interrupted compaction leaves either
//! the old segments or the complete new one, never a mix.

pub mod segment;
pub mod session_store;

pub use session_store::SessionStore;

use std::fs::File;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Mount configuration for the durable tier, carried from the CLI
/// (`ccn serve --store-dir DIR --resident-cap K`) into the shard pool.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Root directory; shard `k` stores under `<dir>/shard-<k>/`.
    pub dir: PathBuf,
    /// Resident sessions each shard keeps in memory before evicting its
    /// least-recently-used to disk. `0` means unlimited (the store still
    /// serves explicit `park` ops and shutdown flushes).
    pub resident_cap: usize,
}

impl StoreConfig {
    pub fn new(dir: impl Into<PathBuf>, resident_cap: usize) -> StoreConfig {
        StoreConfig {
            dir: dir.into(),
            resident_cap,
        }
    }

    /// The per-shard store directory.
    pub fn shard_dir(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("shard-{shard}"))
    }

    /// The pool-wide next-id watermark file (ids are allocated centrally
    /// by the shard pool, so the watermark lives at the root, not in a
    /// shard directory).
    pub fn watermark_path(&self) -> PathBuf {
        self.dir.join("next-id")
    }
}

/// The watermark file is rewritten once per this many allocated ids, not
/// on every `open` — a crash burns at most one chunk of the (64-bit) id
/// space instead of costing a synced write per session.
const WATERMARK_CHUNK: u64 = 1024;

/// Persisted floor for the session-id allocator.
///
/// Boot-time recovery used to start the allocator just above the highest
/// *parked* id — but ids of sessions that were never parked (opened,
/// stepped, lost in a crash) were forgotten and could be handed out
/// again after a restart. A client still holding such an id from before
/// the crash would then silently talk to a stranger's fresh session.
/// The watermark closes that hole: every id the pool hands out is
/// covered by a durable floor *before* the client sees it, and the next
/// boot allocates from `max(highest parked id + 1, floor)`.
///
/// Written atomically (temp file, fsync, rename), so the file always
/// holds a complete value.
pub struct IdWatermark {
    path: PathBuf,
    /// ids below this are burned — never handed out again
    covered: AtomicU64,
    /// serializes file rewrites (readers use `covered` lock-free)
    write_lock: Mutex<()>,
}

impl IdWatermark {
    /// Open (or create-on-first-write) the watermark at `path`. A
    /// missing file means a floor of 0 (fresh store).
    pub fn open(path: PathBuf) -> Result<IdWatermark, String> {
        let floor = match std::fs::read_to_string(&path) {
            Ok(text) => text.trim().parse::<u64>().map_err(|_| {
                format!(
                    "watermark {}: not an integer: {:?}",
                    path.display(),
                    text.trim()
                )
            })?,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => 0,
            Err(e) => return Err(format!("watermark {}: {e}", path.display())),
        };
        Ok(IdWatermark {
            path,
            covered: AtomicU64::new(floor),
            write_lock: Mutex::new(()),
        })
    }

    /// The durable floor: the allocator must start at or above this.
    pub fn floor(&self) -> u64 {
        self.covered.load(Ordering::Acquire)
    }

    /// Make the floor cover `id` durably. A no-op (lock-free) for all
    /// but one in [`WATERMARK_CHUNK`] allocations; when the chunk is
    /// exhausted the next multiple is committed before returning, so an
    /// id is never visible to a client without being burned on disk.
    pub fn ensure_covers(&self, id: u64) -> Result<(), String> {
        if id < self.covered.load(Ordering::Acquire) {
            return Ok(());
        }
        let _guard = self
            .write_lock
            .lock()
            .map_err(|_| "watermark lock poisoned".to_string())?;
        if id < self.covered.load(Ordering::Acquire) {
            return Ok(()); // another allocator raised it while we waited
        }
        let new = (id / WATERMARK_CHUNK + 1).saturating_mul(WATERMARK_CHUNK);
        let tmp = self.path.with_extension("tmp");
        std::fs::write(&tmp, new.to_string())
            .map_err(|e| format!("watermark write: {e}"))?;
        File::open(&tmp)
            .and_then(|f| f.sync_all())
            .map_err(|e| format!("watermark sync: {e}"))?;
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| format!("watermark commit: {e}"))?;
        // make the rename itself durable — without a directory sync the
        // floor bump can vanish in a crash, which is the exact id-reuse
        // hole the watermark exists to close (best effort: not all
        // platforms allow fsync on a directory handle)
        if let Some(parent) = self.path.parent() {
            if let Ok(d) = File::open(parent) {
                let _ = d.sync_all();
            }
        }
        self.covered.store(new, Ordering::Release);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_dir(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let dir = std::env::temp_dir().join(format!(
            "ccn-wm-{tag}-{}-{nanos}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn watermark_opens_empty_persists_in_chunks_and_reloads() {
        let dir = fresh_dir("basic");
        let path = dir.join("next-id");
        let wm = IdWatermark::open(path.clone()).unwrap();
        assert_eq!(wm.floor(), 0);
        wm.ensure_covers(1).unwrap();
        assert_eq!(wm.floor(), WATERMARK_CHUNK);
        // covered ids cost nothing (no rewrite): floor is unchanged
        wm.ensure_covers(500).unwrap();
        assert_eq!(wm.floor(), WATERMARK_CHUNK);
        // crossing the chunk bumps to the next multiple
        wm.ensure_covers(WATERMARK_CHUNK).unwrap();
        assert_eq!(wm.floor(), 2 * WATERMARK_CHUNK);
        drop(wm);
        // a "restarted" allocator reads the burned floor back
        let wm = IdWatermark::open(path).unwrap();
        assert_eq!(wm.floor(), 2 * WATERMARK_CHUNK);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn watermark_rejects_garbage_and_ignores_stale_tmp() {
        let dir = fresh_dir("garbage");
        let path = dir.join("next-id");
        std::fs::write(&path, "not-a-number").unwrap();
        assert!(IdWatermark::open(path.clone()).is_err());
        std::fs::write(&path, "2048").unwrap();
        // a crash between write and rename leaves a .tmp; it must not
        // shadow the committed value and gets overwritten on next bump
        std::fs::write(dir.join("next-id.tmp"), "999999").unwrap();
        let wm = IdWatermark::open(path).unwrap();
        assert_eq!(wm.floor(), 2048);
        wm.ensure_covers(5000).unwrap();
        assert_eq!(wm.floor(), 5 * WATERMARK_CHUNK);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
