//! Segment-file format for the durable session store.
//!
//! A segment is a plain append-only file of newline-delimited JSON
//! records, named `seg-<generation>.log` (zero-padded so lexical order is
//! generation order). Two record shapes exist:
//!
//! ```json
//! {"id":7,"op":"park","state":{"v":2,"kind":"tbptt",...}}
//! {"id":7,"op":"del"}
//! ```
//!
//! `state` is the serve layer's versioned snapshot envelope, carried
//! opaquely — the store never interprets net internals, which is what
//! makes the tier kind-agnostic. [`Json::dump`] never emits raw
//! newlines (control characters are escaped), so one record is always
//! exactly one line and a byte offset + length addresses it uniquely.
//!
//! Crash model: appends can tear, so only the *final* line of a segment
//! may be unparseable — [`read_segment`] reports the length of the valid
//! prefix and the caller truncates before appending again. An invalid
//! line anywhere else is real corruption and is reported as an error
//! rather than silently skipped.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// `seg-0000000042.log`
const PREFIX: &str = "seg-";
const SUFFIX: &str = ".log";

/// Path of the segment file with generation `gen` under `dir`.
pub fn segment_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("{PREFIX}{gen:010}{SUFFIX}"))
}

/// Parse a generation number back out of a segment file name.
pub fn parse_generation(file_name: &str) -> Option<u64> {
    file_name
        .strip_prefix(PREFIX)?
        .strip_suffix(SUFFIX)?
        .parse()
        .ok()
}

/// One durable record: a parked snapshot or a tombstone.
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    Park { id: u64, state: Json },
    Delete { id: u64 },
}

impl Record {
    pub fn id(&self) -> u64 {
        match self {
            Record::Park { id, .. } | Record::Delete { id } => *id,
        }
    }

    /// Encode as a single line (no trailing newline).
    pub fn encode(&self) -> String {
        match self {
            Record::Park { id, state } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::Str("park".into())),
                ("state", state.clone()),
            ])
            .dump(),
            Record::Delete { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::Str("del".into())),
            ])
            .dump(),
        }
    }

    pub fn decode(line: &str) -> Result<Record, String> {
        let v = Json::parse(line).map_err(|e| format!("bad record: {e}"))?;
        let id = v
            .get("id")
            .and_then(|n| n.as_f64())
            .ok_or("record missing numeric 'id'")? as u64;
        match v.get("op").and_then(|o| o.as_str()) {
            Some("park") => {
                let state = v.get("state").ok_or("park record missing 'state'")?;
                Ok(Record::Park {
                    id,
                    state: state.clone(),
                })
            }
            Some("del") => Ok(Record::Delete { id }),
            _ => Err("record missing 'op' (park|del)".into()),
        }
    }
}

/// Append one record to an open segment file; returns `(offset, len)` of
/// the encoded line (len excludes the newline). The write is flushed and
/// synced before returning — a record the store acknowledged survives a
/// crash.
pub fn append_record(
    file: &mut File,
    offset: u64,
    rec: &Record,
) -> Result<(u64, u64), String> {
    let line = rec.encode();
    file.write_all(line.as_bytes())
        .and_then(|()| file.write_all(b"\n"))
        .and_then(|()| file.flush())
        .and_then(|()| file.sync_data())
        .map_err(|e| format!("segment append: {e}"))?;
    Ok((offset, line.len() as u64))
}

/// Replay one segment file: every decoded record with its byte offset
/// and length, plus the length of the valid prefix (== file length unless
/// the final line is torn).
///
/// `tolerate_torn_tail` should be true only for the highest-generation
/// (active) segment — a crash mid-append can only tear the end of the
/// file that was being written.
pub fn read_segment(
    path: &Path,
    tolerate_torn_tail: bool,
) -> Result<(Vec<(u64, u64, Record)>, u64), String> {
    let bytes = std::fs::read(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    let mut out = Vec::new();
    let mut pos: usize = 0;
    while pos < bytes.len() {
        let rel_end = bytes[pos..].iter().position(|&b| b == b'\n');
        let (line_end, complete) = match rel_end {
            Some(r) => (pos + r, true),
            None => (bytes.len(), false),
        };
        let parsed = std::str::from_utf8(&bytes[pos..line_end])
            .ok()
            .map(Record::decode);
        match parsed {
            Some(Ok(rec)) if complete => {
                out.push((pos as u64, (line_end - pos) as u64, rec));
                pos = line_end + 1;
            }
            // incomplete or unparseable final data: torn append
            _ if tolerate_torn_tail && {
                // only torn if nothing but this chunk remains
                !complete
                    || bytes[line_end + 1..]
                        .iter()
                        .all(|&b| b == b'\n' || b == b' ')
            } =>
            {
                return Ok((out, pos as u64));
            }
            _ => {
                return Err(format!(
                    "corrupt record at byte {pos} of {}",
                    path.display()
                ));
            }
        }
    }
    Ok((out, bytes.len() as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_file(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        std::env::temp_dir().join(format!(
            "ccn-seg-{tag}-{}-{nanos}.log",
            std::process::id()
        ))
    }

    fn park(id: u64, mark: &str) -> Record {
        Record::Park {
            id,
            state: Json::obj(vec![
                ("v", Json::Num(2.0)),
                ("kind", Json::Str(mark.into())),
            ]),
        }
    }

    #[test]
    fn record_encode_decode_roundtrip() {
        for rec in [park(3, "columnar"), Record::Delete { id: 9 }] {
            let line = rec.encode();
            assert!(!line.contains('\n'), "records must be single lines");
            assert_eq!(Record::decode(&line).unwrap(), rec);
        }
        assert!(Record::decode("{}").is_err());
        assert!(Record::decode(r#"{"id":1,"op":"park"}"#).is_err());
        assert!(Record::decode("not json").is_err());
    }

    #[test]
    fn segment_names_roundtrip_and_sort() {
        let dir = PathBuf::from("/x");
        let p = segment_path(&dir, 42);
        let name = p.file_name().unwrap().to_str().unwrap().to_string();
        assert_eq!(parse_generation(&name), Some(42));
        assert_eq!(parse_generation("seg-abc.log"), None);
        assert_eq!(parse_generation("other.log"), None);
        // zero padding keeps lexical order == numeric order
        let a = segment_path(&dir, 9);
        let b = segment_path(&dir, 10);
        assert!(a.file_name().unwrap() < b.file_name().unwrap());
    }

    #[test]
    fn append_and_read_back() {
        let path = tmp_file("rw");
        let mut f = File::create(&path).unwrap();
        let mut off = 0;
        let recs = vec![park(1, "a"), Record::Delete { id: 1 }, park(2, "b")];
        for r in &recs {
            let (o, l) = append_record(&mut f, off, r).unwrap();
            assert_eq!(o, off);
            off = o + l + 1;
        }
        let (got, valid) = read_segment(&path, false).unwrap();
        assert_eq!(valid, off);
        assert_eq!(got.len(), 3);
        for ((o, l, rec), want) in got.iter().zip(&recs) {
            assert_eq!(rec, want);
            // the (offset, len) pair must address exactly the record
            let bytes = std::fs::read(&path).unwrap();
            let line =
                std::str::from_utf8(&bytes[*o as usize..(*o + *l) as usize])
                    .unwrap();
            assert_eq!(&Record::decode(line).unwrap(), want);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn torn_tail_is_tolerated_only_when_asked() {
        let path = tmp_file("torn");
        let mut f = File::create(&path).unwrap();
        let (o, l) = append_record(&mut f, 0, &park(5, "x")).unwrap();
        let good_len = o + l + 1;
        // simulate a torn append: half a record, no newline
        f.write_all(b"{\"id\":6,\"op\":\"pa").unwrap();
        f.flush().unwrap();
        let (recs, valid) = read_segment(&path, true).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(valid, good_len, "valid prefix ends after the good record");
        assert!(read_segment(&path, false).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
