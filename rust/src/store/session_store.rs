//! The durable session store: an append-compact log of parked session
//! snapshots with an in-memory index.
//!
//! One store owns one directory (the serve layer gives each shard its
//! own, so stores are single-writer by construction). State lives in
//! numbered segment files ([`super::segment`]); the index maps session id
//! to the `(generation, offset, len)` of its newest `park` record, plus
//! the envelope's `kind` tag so stats never have to touch disk.
//!
//! Write path: `park`/`delete` append one synced record to the active
//! segment. Overwritten and deleted records become dead bytes; when dead
//! bytes exceed both a floor and the live volume, [`SessionStore`]
//! compacts — all live records are copied byte-for-byte into a fresh
//! segment written to a temp file, synced, and atomically renamed into
//! place before the old segments are unlinked. A crash at any point
//! leaves either the old segments or the complete new one.
//!
//! Read path: `load` seeks straight to the indexed record; `scan` is the
//! boot-time replay that rebuilds the index (tolerating a torn final
//! append, the only kind of damage a crash can inflict).

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::segment::{
    append_record, parse_generation, read_segment, segment_path, Record,
};

/// Where the newest record for a session id lives.
#[derive(Clone, Debug)]
struct IndexEntry {
    gen: u64,
    offset: u64,
    len: u64,
    /// the envelope's `kind` tag, cached for stats / boot validation
    kind: String,
}

/// Durable, crash-recoverable store of parked session envelopes.
pub struct SessionStore {
    dir: PathBuf,
    index: HashMap<u64, IndexEntry>,
    active_gen: u64,
    active: File,
    active_len: u64,
    /// bytes of indexed (live) records
    live_bytes: u64,
    /// bytes of superseded records and tombstones across all segments
    dead_bytes: u64,
    /// seal the active segment when it grows past this
    pub roll_bytes: u64,
    /// compact when dead bytes exceed max(this, live bytes)
    pub compact_min_dead: u64,
    /// optional latency observer: each compaction pass that actually
    /// runs records its wall time (ns). Measurement-only.
    compact_obs: Option<std::sync::Arc<crate::obs::Histogram>>,
}

impl SessionStore {
    /// Open (or create) the store rooted at `dir`, replaying every
    /// segment to rebuild the index. A torn final append is truncated
    /// away; any other damage is an error.
    pub fn open(dir: impl Into<PathBuf>) -> Result<SessionStore, String> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("store: create {}: {e}", dir.display()))?;
        // Single-writer guard (best effort in a zero-dep build): a pid
        // lock file. A live foreign pid refuses the mount — two writers
        // would unlink each other's segments under compaction; a stale
        // pid (crashed predecessor) is taken over silently, so crash
        // recovery never needs manual lock removal.
        let lock_path = dir.join("LOCK");
        if let Ok(prev) = std::fs::read_to_string(&lock_path) {
            if let Ok(pid) = prev.trim().parse::<u32>() {
                if pid != std::process::id()
                    && Path::new(&format!("/proc/{pid}")).exists()
                {
                    return Err(format!(
                        "store {} is locked by live process {pid}",
                        dir.display()
                    ));
                }
            }
        }
        std::fs::write(&lock_path, std::process::id().to_string())
            .map_err(|e| format!("store: write lock: {e}"))?;
        let mut gens: Vec<u64> = Vec::new();
        for entry in std::fs::read_dir(&dir)
            .map_err(|e| format!("store: list {}: {e}", dir.display()))?
        {
            let entry = entry.map_err(|e| format!("store: list: {e}"))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.ends_with(".tmp") {
                // leftover from a compaction that never committed
                let _ = std::fs::remove_file(entry.path());
            } else if let Some(gen) = parse_generation(&name) {
                gens.push(gen);
            }
        }
        gens.sort_unstable();

        let mut index: HashMap<u64, IndexEntry> = HashMap::new();
        let mut live_bytes = 0u64;
        let mut dead_bytes = 0u64;
        let mut active_len = 0u64;
        for (i, &gen) in gens.iter().enumerate() {
            let last = i + 1 == gens.len();
            let path = segment_path(&dir, gen);
            let (records, valid_len) = read_segment(&path, last)?;
            let file_len = std::fs::metadata(&path)
                .map_err(|e| format!("store: stat: {e}"))?
                .len();
            if valid_len < file_len {
                // torn append: drop the partial record before reuse
                OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .and_then(|f| f.set_len(valid_len))
                    .map_err(|e| format!("store: truncate torn tail: {e}"))?;
            }
            if last {
                active_len = valid_len;
            }
            for (offset, len, rec) in records {
                match rec {
                    Record::Park { id, state } => {
                        if let Some(old) = index.remove(&id) {
                            live_bytes -= old.len;
                            dead_bytes += old.len;
                        }
                        let kind = state
                            .get("kind")
                            .and_then(|k| k.as_str())
                            .unwrap_or("?")
                            .to_string();
                        live_bytes += len;
                        index.insert(
                            id,
                            IndexEntry {
                                gen,
                                offset,
                                len,
                                kind,
                            },
                        );
                    }
                    Record::Delete { id } => {
                        if let Some(old) = index.remove(&id) {
                            live_bytes -= old.len;
                            dead_bytes += old.len;
                        }
                        dead_bytes += len;
                    }
                }
            }
        }
        let active_gen = gens.last().copied().unwrap_or(1);
        let active = OpenOptions::new()
            .create(true)
            .append(true)
            .open(segment_path(&dir, active_gen))
            .map_err(|e| format!("store: open active segment: {e}"))?;
        Ok(SessionStore {
            dir,
            index,
            active_gen,
            active,
            active_len,
            live_bytes,
            dead_bytes,
            roll_bytes: 4 << 20,
            compact_min_dead: 64 << 10,
            compact_obs: None,
        })
    }

    /// Record each actual compaction pass's wall time into `hist` (the
    /// serve layer wires in its `stage.store_compact` histogram).
    pub fn set_compact_observer(
        &mut self,
        hist: std::sync::Arc<crate::obs::Histogram>,
    ) {
        self.compact_obs = Some(hist);
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of parked sessions.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.index.contains_key(&id)
    }

    /// Parked session ids, ascending.
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.index.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// The cached envelope `kind` tag of a parked session.
    pub fn kind_of(&self, id: u64) -> Option<&str> {
        self.index.get(&id).map(|e| e.kind.as_str())
    }

    /// Parked session counts per envelope kind.
    pub fn kind_counts(&self) -> BTreeMap<String, usize> {
        let mut counts = BTreeMap::new();
        for e in self.index.values() {
            *counts.entry(e.kind.clone()).or_insert(0) += 1;
        }
        counts
    }

    /// Total on-disk record volume (live + dead).
    pub fn bytes(&self) -> u64 {
        self.live_bytes + self.dead_bytes
    }

    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Durably park a session envelope under `id`, replacing any previous
    /// snapshot. The envelope must be an object carrying the versioned
    /// `"v"`/`"kind"` tags (the store stays agnostic to everything else).
    pub fn park(&mut self, id: u64, state: &Json) -> Result<(), String> {
        if state.get("v").and_then(|v| v.as_f64()).is_none() {
            return Err("store: envelope missing version tag 'v'".into());
        }
        let kind = state
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or("store: envelope missing 'kind' tag")?
            .to_string();
        self.maybe_roll()?;
        let rec = Record::Park {
            id,
            state: state.clone(),
        };
        let (offset, len) = append_record(&mut self.active, self.active_len, &rec)?;
        self.active_len = offset + len + 1;
        if let Some(old) = self.index.remove(&id) {
            self.live_bytes -= old.len;
            self.dead_bytes += old.len;
        }
        self.live_bytes += len;
        self.index.insert(
            id,
            IndexEntry {
                gen: self.active_gen,
                offset,
                len,
                kind,
            },
        );
        self.maybe_compact()
    }

    /// Load the parked envelope for `id` straight from its segment.
    pub fn load(&self, id: u64) -> Result<Json, String> {
        let entry = self
            .index
            .get(&id)
            .ok_or_else(|| format!("store: no parked session {id}"))?;
        let path = segment_path(&self.dir, entry.gen);
        let mut f = File::open(&path)
            .map_err(|e| format!("store: open {}: {e}", path.display()))?;
        f.seek(SeekFrom::Start(entry.offset))
            .map_err(|e| format!("store: seek: {e}"))?;
        let mut buf = vec![0u8; entry.len as usize];
        f.read_exact(&mut buf)
            .map_err(|e| format!("store: read record: {e}"))?;
        let line = std::str::from_utf8(&buf)
            .map_err(|_| "store: record is not utf-8".to_string())?;
        match Record::decode(line)? {
            Record::Park { id: got, state } if got == id => Ok(state),
            _ => Err(format!("store: index points at a foreign record for {id}")),
        }
    }

    /// Remove a parked session (appends a tombstone). Returns whether the
    /// id was present. The tombstone hits disk *before* the index
    /// forgets the id — a failed append leaves memory and disk agreeing
    /// that the session still exists, instead of a phantom delete that
    /// resurrects on the next boot.
    pub fn delete(&mut self, id: u64) -> Result<bool, String> {
        let Some(old_len) = self.index.get(&id).map(|e| e.len) else {
            return Ok(false);
        };
        self.maybe_roll()?;
        let (offset, len) =
            append_record(&mut self.active, self.active_len, &Record::Delete { id })?;
        self.active_len = offset + len + 1;
        self.index.remove(&id);
        self.live_bytes -= old_len;
        self.dead_bytes += old_len + len;
        self.maybe_compact()?;
        Ok(true)
    }

    /// Every parked `(id, envelope)`, ascending by id — the boot-time
    /// resume path and the migration path both drive this.
    pub fn scan(&self) -> Result<Vec<(u64, Json)>, String> {
        self.ids()
            .into_iter()
            .map(|id| Ok((id, self.load(id)?)))
            .collect()
    }

    /// Seal the active segment and start a new one when it is large.
    fn maybe_roll(&mut self) -> Result<(), String> {
        if self.active_len < self.roll_bytes {
            return Ok(());
        }
        self.active_gen += 1;
        self.active = OpenOptions::new()
            .create_new(true)
            .append(true)
            .open(segment_path(&self.dir, self.active_gen))
            .map_err(|e| format!("store: roll segment: {e}"))?;
        self.active_len = 0;
        Ok(())
    }

    /// Copy all live records into one fresh segment (write temp file,
    /// sync, rename) and unlink the old segments.
    fn maybe_compact(&mut self) -> Result<(), String> {
        if self.dead_bytes < self.compact_min_dead.max(self.live_bytes) {
            return Ok(());
        }
        use std::io::Write as _;
        // clock only passes that run; the early return above is free
        let compact_start = std::time::Instant::now();
        let compact_gen = self.active_gen + 1;
        let tmp_path = self.dir.join("compact.tmp");
        let mut tmp = File::create(&tmp_path)
            .map_err(|e| format!("store: create compact.tmp: {e}"))?;
        // copy record lines byte-for-byte, grouped by source segment so
        // each old file is read once
        let mut by_gen: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
        for (&id, e) in &self.index {
            by_gen.entry(e.gen).or_default().push(id);
        }
        let mut new_index: HashMap<u64, IndexEntry> = HashMap::new();
        let mut offset = 0u64;
        for (gen, mut ids) in by_gen {
            ids.sort_unstable();
            let path = segment_path(&self.dir, gen);
            let mut src = File::open(&path)
                .map_err(|e| format!("store: open {}: {e}", path.display()))?;
            for id in ids {
                let entry = &self.index[&id];
                src.seek(SeekFrom::Start(entry.offset))
                    .map_err(|e| format!("store: seek: {e}"))?;
                let mut buf = vec![0u8; entry.len as usize];
                src.read_exact(&mut buf)
                    .map_err(|e| format!("store: read record: {e}"))?;
                tmp.write_all(&buf)
                    .and_then(|()| tmp.write_all(b"\n"))
                    .map_err(|e| format!("store: compact write: {e}"))?;
                new_index.insert(
                    id,
                    IndexEntry {
                        gen: compact_gen,
                        offset,
                        len: entry.len,
                        kind: entry.kind.clone(),
                    },
                );
                offset += entry.len + 1;
            }
        }
        tmp.sync_all()
            .map_err(|e| format!("store: compact sync: {e}"))?;
        drop(tmp);
        let compact_path = segment_path(&self.dir, compact_gen);
        std::fs::rename(&tmp_path, &compact_path)
            .map_err(|e| format!("store: commit compaction: {e}"))?;
        // make the rename itself durable (best effort: not all platforms
        // allow fsync on a directory handle)
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        // the compacted segment is sealed; appends continue in a fresh one
        let old_last = self.active_gen;
        self.active_gen = compact_gen + 1;
        self.active = OpenOptions::new()
            .create_new(true)
            .append(true)
            .open(segment_path(&self.dir, self.active_gen))
            .map_err(|e| format!("store: post-compact segment: {e}"))?;
        self.active_len = 0;
        for gen in (0..=old_last).rev() {
            let _ = std::fs::remove_file(segment_path(&self.dir, gen));
        }
        self.index = new_index;
        self.dead_bytes = 0;
        // live_bytes is unchanged: the same records, new home
        if let Some(h) = &self.compact_obs {
            h.record_duration(compact_start.elapsed());
        }
        Ok(())
    }
}

impl Drop for SessionStore {
    fn drop(&mut self) {
        // release the pid lock on clean teardown; a crash leaves it
        // behind and the stale-pid check in `open` takes over
        let lock_path = self.dir.join("LOCK");
        if let Ok(prev) = std::fs::read_to_string(&lock_path) {
            if prev.trim() == std::process::id().to_string() {
                let _ = std::fs::remove_file(&lock_path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh_dir(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        std::env::temp_dir().join(format!(
            "ccn-store-{tag}-{}-{nanos}",
            std::process::id()
        ))
    }

    fn envelope(kind: &str, mark: f64) -> Json {
        Json::obj(vec![
            ("v", Json::Num(2.0)),
            ("kind", Json::Str(kind.into())),
            ("net", Json::obj(vec![("mark", Json::Num(mark))])),
        ])
    }

    #[test]
    fn park_load_delete_scan_roundtrip() {
        let dir = fresh_dir("crud");
        let mut s = SessionStore::open(&dir).unwrap();
        assert!(s.is_empty());
        s.park(1, &envelope("columnar", 1.0)).unwrap();
        s.park(2, &envelope("tbptt", 2.0)).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.contains(1) && s.contains(2) && !s.contains(3));
        assert_eq!(s.load(1).unwrap(), envelope("columnar", 1.0));
        assert_eq!(s.kind_of(2), Some("tbptt"));
        // overwrite keeps the newest
        s.park(1, &envelope("columnar", 9.0)).unwrap();
        assert_eq!(s.load(1).unwrap(), envelope("columnar", 9.0));
        assert_eq!(s.len(), 2);
        // scan returns everything in id order
        let all = s.scan().unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].0, 1);
        assert_eq!(all[1].1, envelope("tbptt", 2.0));
        // delete
        assert!(s.delete(1).unwrap());
        assert!(!s.delete(1).unwrap(), "double delete is a no-op");
        assert!(s.load(1).is_err());
        assert_eq!(s.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn park_rejects_untagged_envelopes() {
        let dir = fresh_dir("tags");
        let mut s = SessionStore::open(&dir).unwrap();
        let no_kind = Json::obj(vec![("v", Json::Num(2.0))]);
        assert!(s.park(1, &no_kind).is_err());
        let no_v = Json::obj(vec![("kind", Json::Str("ccn".into()))]);
        assert!(s.park(1, &no_v).is_err());
        assert!(s.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_recovers_index_and_bytes() {
        let dir = fresh_dir("reopen");
        {
            let mut s = SessionStore::open(&dir).unwrap();
            for id in 1..=5 {
                s.park(id, &envelope("snap1", id as f64)).unwrap();
            }
            s.delete(3).unwrap();
            s.park(2, &envelope("snap1", 22.0)).unwrap();
        } // dropped without any shutdown hook: durability is per-append
        let s = SessionStore::open(&dir).unwrap();
        assert_eq!(s.ids(), vec![1, 2, 4, 5]);
        assert_eq!(s.load(2).unwrap(), envelope("snap1", 22.0));
        assert_eq!(s.load(4).unwrap(), envelope("snap1", 4.0));
        assert_eq!(s.kind_counts().get("snap1"), Some(&4));
        assert!(s.bytes() > s.live_bytes());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated_on_open() {
        let dir = fresh_dir("torn");
        {
            let mut s = SessionStore::open(&dir).unwrap();
            s.park(1, &envelope("ccn", 1.0)).unwrap();
        }
        // simulate a crash mid-append: garbage half-record at the tail
        let seg = segment_path(&dir, 1);
        let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
        use std::io::Write as _;
        f.write_all(b"{\"id\":2,\"op\":\"park\",\"state\":{\"v\"").unwrap();
        drop(f);
        let mut s = SessionStore::open(&dir).unwrap();
        assert_eq!(s.ids(), vec![1], "torn record must not surface");
        // the truncated segment accepts appends again, at a valid offset
        s.park(2, &envelope("ccn", 2.0)).unwrap();
        assert_eq!(s.load(2).unwrap(), envelope("ccn", 2.0));
        drop(s);
        let s = SessionStore::open(&dir).unwrap();
        assert_eq!(s.ids(), vec![1, 2]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compaction_drops_dead_bytes_and_preserves_live_state() {
        let dir = fresh_dir("compact");
        let mut s = SessionStore::open(&dir).unwrap();
        s.roll_bytes = 512; // force rolling across several segments
        s.compact_min_dead = 256;
        for round in 0..20 {
            for id in 1..=4u64 {
                s.park(id, &envelope("columnar", (round * 10 + id as usize) as f64))
                    .unwrap();
            }
        }
        // overwrites dominate: compaction must have fired at least once
        assert!(
            s.dead_bytes < s.live_bytes + s.compact_min_dead,
            "dead bytes stay bounded: dead={} live={}",
            s.dead_bytes,
            s.live_bytes
        );
        for id in 1..=4u64 {
            assert_eq!(
                s.load(id).unwrap(),
                envelope("columnar", (190 + id as usize) as f64),
                "newest snapshot survives compaction"
            );
        }
        // no stale segments or temp files left behind
        let files: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(files.iter().all(|f| !f.ends_with(".tmp")));
        // reopen agrees byte for byte
        let ids_before = s.ids();
        drop(s);
        let s = SessionStore::open(&dir).unwrap();
        assert_eq!(s.ids(), ids_before);
        for id in 1..=4u64 {
            assert_eq!(
                s.load(id).unwrap(),
                envelope("columnar", (190 + id as usize) as f64)
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn second_writer_is_refused_while_lock_is_live() {
        let dir = fresh_dir("lock");
        let s = SessionStore::open(&dir).unwrap();
        drop(s);
        assert!(!dir.join("LOCK").exists(), "clean drop releases the lock");
        // a live foreign pid refuses the mount (pid 1 always exists)
        std::fs::write(dir.join("LOCK"), "1").unwrap();
        let err = SessionStore::open(&dir).unwrap_err();
        assert!(err.contains("locked by live process 1"), "{err}");
        // a stale pid (crashed predecessor) is taken over silently
        std::fs::write(dir.join("LOCK"), "999999999").unwrap();
        let s = SessionStore::open(&dir).unwrap();
        assert!(s.is_empty());
        drop(s);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn interrupted_compaction_leaves_a_consistent_store() {
        let dir = fresh_dir("tmpclean");
        {
            let mut s = SessionStore::open(&dir).unwrap();
            s.park(7, &envelope("tbptt", 7.0)).unwrap();
        }
        // a compaction that died before the rename leaves only a .tmp
        std::fs::write(dir.join("compact.tmp"), b"half-written garbage").unwrap();
        let s = SessionStore::open(&dir).unwrap();
        assert_eq!(s.ids(), vec![7]);
        assert!(
            !dir.join("compact.tmp").exists(),
            "stale temp files are cleaned up"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
