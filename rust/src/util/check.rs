//! Mini property-testing framework (no `proptest` available offline).
//!
//! A property is a closure over a seeded RNG; [`check`] runs it across many
//! seeds and reports the first failing seed with a deterministic repro. A
//! light "shrink" is provided for integer-sized cases via [`Gen::size`]
//! bias: early cases draw small sizes so the first failure tends to be
//! near-minimal.
//!
//! ```ignore
//! check("normalizer bounded", 200, |g| {
//!     let v = g.f32_in(-10.0, 10.0);
//!     prop_assert(v.abs() <= 10.0, format!("v = {v}"))
//! });
//! ```

use super::prng::Xoshiro256;

/// Case generator handed to properties: seeded RNG + a size hint that
/// grows with the case index (so early failures are small).
pub struct Gen {
    pub rng: Xoshiro256,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.int_in(lo as u64, hi as u64) as usize
    }

    /// Integer in [lo, hi] biased toward `lo + size` early in a run.
    pub fn sized_usize(&mut self, lo: usize, hi: usize) -> usize {
        let cap = (lo + self.size).min(hi);
        self.usize_in(lo, cap)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Result of one property case.
pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn prop_close(a: f32, b: f32, tol: f32, what: &str) -> PropResult {
    let denom = 1.0f32.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * denom {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of the property; panic with seed on failure.
///
/// Seeds are derived deterministically from the property name so runs are
/// reproducible without a lockfile, and independent across properties.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let name_seed = fnv1a(name.as_bytes());
    for i in 0..cases {
        let mut g = Gen {
            rng: Xoshiro256::seed_from_u64(name_seed ^ (i as u64).wrapping_mul(0x9E3779B9)),
            size: 1 + i * 64 / cases.max(1),
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {i} (seed base {name_seed:#x}): {msg}"
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always true", 50, |_g| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_name() {
        check("always false", 10, |_g| Err("nope".to_string()));
    }

    #[test]
    fn sized_grows() {
        let mut max_early = 0;
        let mut max_late = 0;
        let mut i = 0;
        check("size grows", 100, |g| {
            let v = g.sized_usize(1, 1000);
            if i < 10 {
                max_early = max_early.max(v);
            }
            if i >= 90 {
                max_late = max_late.max(v);
            }
            i += 1;
            Ok(())
        });
        assert!(max_early <= 12, "early cases should be small: {max_early}");
        assert!(max_late > max_early);
    }

    #[test]
    fn prop_close_relative() {
        assert!(prop_close(1000.0, 1000.5, 1e-3, "x").is_ok());
        assert!(prop_close(1.0, 1.5, 1e-3, "x").is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<u64> = Vec::new();
        check("det", 5, |g| {
            first.push(g.rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("det", 5, |g| {
            second.push(g.rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
