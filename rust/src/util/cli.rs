//! Tiny CLI argument parser (no `clap` available offline).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value | --key=value] [pos..]`.
//! Typed accessors with defaults; unknown-flag detection via [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    /// Every value given for an option, in argv order — repeatable
    /// options (`--backend A --backend B`) keep them all; the scalar
    /// accessors take the last, matching the usual CLI override rule.
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = first arg, no argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut it = items.into_iter().peekable();
        let mut subcommand = None;
        let mut positional = Vec::new();
        let mut options: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags = Vec::new();

        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    options.entry(k.to_string()).or_default().push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    options
                        .entry(body.to_string())
                        .or_default()
                        .push(it.next().unwrap());
                } else {
                    flags.push(body.to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Args {
            subcommand,
            positional,
            options,
            flags,
            consumed: Vec::new(),
        }
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.options.get(name).and_then(|vs| vs.last().cloned())
    }

    /// Every value a repeatable option was given, in argv order
    /// (`--backend A --backend B` → `["A", "B"]`); empty if absent.
    pub fn opt_str_all(&mut self, name: &str) -> Vec<String> {
        self.consumed.push(name.to_string());
        self.options.get(name).cloned().unwrap_or_default()
    }

    pub fn str_or(&mut self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or_else(|| default.to_string())
    }

    pub fn opt_f64(&mut self, name: &str) -> Option<f64> {
        self.opt_str(name).and_then(|v| v.parse().ok())
    }

    pub fn f64_or(&mut self, name: &str, default: f64) -> f64 {
        self.opt_f64(name).unwrap_or(default)
    }

    pub fn opt_usize(&mut self, name: &str) -> Option<usize> {
        self.opt_str(name).and_then(|v| v.parse().ok())
    }

    pub fn usize_or(&mut self, name: &str, default: usize) -> usize {
        self.opt_usize(name).unwrap_or(default)
    }

    pub fn u64_or(&mut self, name: &str, default: u64) -> u64 {
        self.opt_str(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list: `--seeds 0,1,2`.
    pub fn usize_list_or(&mut self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.opt_str(name) {
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Error if any provided option/flag was never consumed (typo guard).
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown option(s): {}",
                unknown
                    .iter()
                    .map(|s| format!("--{s}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let mut a = args(&["run", "--steps", "1000", "--alpha=0.01", "--quiet"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.usize_or("steps", 0), 1000);
        assert_eq!(a.f64_or("alpha", 0.0), 0.01);
        assert!(a.flag("quiet"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let mut a = args(&["run"]);
        assert_eq!(a.usize_or("steps", 5), 5);
        assert_eq!(a.str_or("env", "trace"), "trace");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_detected() {
        let mut a = args(&["run", "--oops", "1"]);
        let _ = a.usize_or("steps", 5);
        assert!(a.finish().is_err());
    }

    #[test]
    fn lists_parse() {
        let mut a = args(&["sweep", "--seeds", "0,1,2,3"]);
        assert_eq!(a.usize_list_or("seeds", &[9]), vec![0, 1, 2, 3]);
        let mut b = args(&["sweep"]);
        assert_eq!(b.usize_list_or("seeds", &[9]), vec![9]);
    }

    #[test]
    fn positional_and_trailing_flag() {
        // Convention: `--name value` binds the next token unless it starts
        // with `--`; bare flags therefore go last or use `--flag` alone.
        let mut a = args(&["run", "path/to/file", "--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["path/to/file"]);
        // the binding form:
        let mut b = args(&["run", "--out", "path/to/file", "--fast"]);
        assert_eq!(b.opt_str("out").as_deref(), Some("path/to/file"));
        assert!(b.flag("fast"));
    }

    #[test]
    fn no_subcommand_when_leading_dash() {
        let a = args(&["--help"]);
        assert_eq!(a.subcommand, None);
    }

    #[test]
    fn repeated_options_keep_every_value_scalar_takes_last() {
        let mut a = args(&[
            "route", "--backend", "tcp://a:1", "--backend=tcp://b:2",
            "--backend", "tcp://c:3", "--shards", "2", "--shards", "4",
        ]);
        assert_eq!(
            a.opt_str_all("backend"),
            vec!["tcp://a:1", "tcp://b:2", "tcp://c:3"]
        );
        assert_eq!(a.usize_or("shards", 0), 4, "last value wins");
        assert!(a.finish().is_ok());
        let mut b = args(&["route"]);
        assert!(b.opt_str_all("backend").is_empty());
    }
}
