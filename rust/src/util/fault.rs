//! `fault` — deterministic, zero-dependency fault injection for the
//! serving stack.
//!
//! Chaos testing is only useful when a failure found once can be found
//! again. A [`FaultPlan`] makes every injected fault a pure function of
//! `(seed, point name, per-point hit index)` — no wall clock, no global
//! RNG — so the same plan driven through the same sequence of hits
//! fires the exact same faults, in unit tests, the e2e chaos soak, and
//! the `perf_chaos` bench alike.
//!
//! # Spec grammar
//!
//! One plan is configured from a single string (the `CCN_FAULTS` env
//! var or the `--faults` flag):
//!
//! ```text
//! seed:7;client.request:drop:0.05;transport.read:delay:0.2:5
//! ```
//!
//! `;`-separated segments: an optional `seed:N` (default 0), then one
//! rule per named injection point as `point:action:prob[:ms]`. Actions
//! are `drop` (lose the unit of work), `delay` (sleep `ms`
//! milliseconds, required for `delay` only), `dup` (perform it twice)
//! and `truncate` (cut it short). Probability is per *hit* of the
//! point, in `[0, 1]`.
//!
//! # Injection points
//!
//! | point | where | drop means |
//! |-------|-------|------------|
//! | `client.request` | [`crate::cluster::client::WireClient`] before the request write | request lost before send (connection dropped) |
//! | `transport.read` | server reader after a complete request line | connection dropped before execution |
//! | `transport.write` | server writer before a reply line | reply lost (client must time out) |
//! | `store.append` | shard before a store park/append | synthetic store write error |
//! | `store.load` | shard before a store load | synthetic store read error |
//! | `shard.enqueue` | pool before the shard mpsc send | op never reaches its shard worker |
//!
//! The plan is process-global ([`install`] / [`install_from_env`]) so
//! deep call sites don't thread a handle; when nothing is installed the
//! per-hit check is one relaxed atomic load. Because hit counters are
//! process-global too, tests that install a plan must own the whole
//! process (the chaos e2e lives in its own test binary for exactly this
//! reason); plan-level unit tests use [`FaultPlan::decide`] directly on
//! local instances.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// What to do to the unit of work at an injection point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Lose it: the request/reply/record never happens.
    Drop,
    /// Stall it for this many milliseconds, then proceed normally.
    Delay(u64),
    /// Perform it twice.
    Dup,
    /// Cut it short (a partial write, a half line).
    Truncate,
}

/// Longest injectable delay — a typo'd `delay:1.0:9999999` must slow a
/// test down, not wedge it past its CI timeout.
const MAX_DELAY_MS: u64 = 10_000;

struct PointRule {
    action: FaultAction,
    prob: f64,
    hits: AtomicU64,
    fired: AtomicU64,
}

/// A seeded, named-point fault schedule. See the module docs for the
/// spec grammar and the determinism contract.
pub struct FaultPlan {
    seed: u64,
    rules: BTreeMap<String, PointRule>,
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// splitmix64 finalizer: avalanche a 64-bit input.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// The top 53 bits of an avalanched u64 as a uniform f64 in [0, 1).
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// Parse a plan from the spec grammar (see module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut seed = 0u64;
        let mut rules = BTreeMap::new();
        for seg in spec.split(';') {
            let seg = seg.trim();
            if seg.is_empty() {
                continue;
            }
            let parts: Vec<&str> = seg.split(':').collect();
            if parts[0] == "seed" {
                if parts.len() != 2 {
                    return Err(format!("faults: seed segment '{seg}' wants seed:N"));
                }
                seed = parts[1]
                    .parse()
                    .map_err(|_| format!("faults: bad seed '{}'", parts[1]))?;
                continue;
            }
            if parts.len() < 3 {
                return Err(format!(
                    "faults: rule '{seg}' wants point:action:prob[:ms]"
                ));
            }
            let (point, action_name, prob_s) = (parts[0], parts[1], parts[2]);
            let prob: f64 = prob_s
                .parse()
                .map_err(|_| format!("faults: bad probability '{prob_s}' in '{seg}'"))?;
            if !(0.0..=1.0).contains(&prob) {
                return Err(format!(
                    "faults: probability {prob} in '{seg}' is outside [0, 1]"
                ));
            }
            let action = match action_name {
                "drop" => FaultAction::Drop,
                "dup" => FaultAction::Dup,
                "truncate" => FaultAction::Truncate,
                "delay" => {
                    let ms: u64 = parts
                        .get(3)
                        .ok_or_else(|| {
                            format!("faults: delay rule '{seg}' wants point:delay:prob:ms")
                        })?
                        .parse()
                        .map_err(|_| format!("faults: bad delay ms in '{seg}'"))?;
                    FaultAction::Delay(ms.min(MAX_DELAY_MS))
                }
                other => {
                    return Err(format!(
                        "faults: unknown action '{other}' in '{seg}' \
                         (want drop|delay|dup|truncate)"
                    ))
                }
            };
            if action_name != "delay" && parts.len() > 3 {
                return Err(format!("faults: trailing fields in '{seg}'"));
            }
            if rules
                .insert(
                    point.to_string(),
                    PointRule {
                        action,
                        prob,
                        hits: AtomicU64::new(0),
                        fired: AtomicU64::new(0),
                    },
                )
                .is_some()
            {
                return Err(format!("faults: duplicate rule for point '{point}'"));
            }
        }
        Ok(FaultPlan { seed, rules })
    }

    /// Decide the fate of one hit of `point`. Stateless-deterministic:
    /// the decision is `f(seed, point, hit_index)` where `hit_index` is
    /// this plan's running count of hits at that point — two plans with
    /// the same spec, driven through the same hit sequence, fire
    /// identically.
    pub fn decide(&self, point: &str) -> Option<FaultAction> {
        let rule = self.rules.get(point)?;
        let hit = rule.hits.fetch_add(1, Ordering::Relaxed);
        let r = mix(self.seed ^ mix(fnv1a(point) ^ hit));
        if unit(r) < rule.prob {
            rule.fired.fetch_add(1, Ordering::Relaxed);
            Some(rule.action)
        } else {
            None
        }
    }

    /// Order-independent digest of the plan's observed schedule: folds
    /// `(point name, hits, fired)` over rules in name order. Two runs
    /// that drove the same hit sequence through equal plans digest
    /// equal — the reproducibility check the chaos soak asserts.
    pub fn schedule_digest(&self) -> u64 {
        let mut d = mix(self.seed ^ 0x9e37_79b9_7f4a_7c15);
        for (name, rule) in &self.rules {
            d = mix(
                d ^ fnv1a(name)
                    ^ rule.hits.load(Ordering::Relaxed).rotate_left(17)
                    ^ rule.fired.load(Ordering::Relaxed).rotate_left(43),
            );
        }
        d
    }

    /// `(hits, fired)` totals for one point — test introspection.
    pub fn point_counts(&self, point: &str) -> (u64, u64) {
        match self.rules.get(point) {
            Some(r) => (
                r.hits.load(Ordering::Relaxed),
                r.fired.load(Ordering::Relaxed),
            ),
            None => (0, 0),
        }
    }
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<Mutex<Option<Arc<FaultPlan>>>> = OnceLock::new();

fn slot() -> &'static Mutex<Option<Arc<FaultPlan>>> {
    GLOBAL.get_or_init(|| Mutex::new(None))
}

fn relock(
    m: &Mutex<Option<Arc<FaultPlan>>>,
) -> std::sync::MutexGuard<'_, Option<Arc<FaultPlan>>> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Install (or with `None`, clear) the process-global plan.
pub fn install(plan: Option<FaultPlan>) {
    let mut g = relock(slot());
    *g = plan.map(Arc::new);
    ACTIVE.store(g.is_some(), Ordering::Release);
}

/// Install the global plan from `CCN_FAULTS` if set and non-empty.
/// Returns whether a plan was installed; a malformed spec is an error
/// (silently serving without requested chaos would be worse).
pub fn install_from_env() -> Result<bool, String> {
    match std::env::var("CCN_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            install(Some(FaultPlan::parse(&spec)?));
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// One hit of `point` against the global plan. With no plan installed
/// this is a single relaxed atomic load — cheap enough for every
/// request path.
#[inline]
pub fn hit(point: &str) -> Option<FaultAction> {
    if !ACTIVE.load(Ordering::Acquire) {
        return None;
    }
    let plan = relock(slot()).clone()?;
    plan.decide(point)
}

/// The global plan's [`FaultPlan::schedule_digest`], if one is
/// installed.
pub fn global_digest() -> Option<u64> {
    relock(slot()).clone().map(|p| p.schedule_digest())
}

/// Injected-delay sleep (bounded by the parse-time cap).
pub fn sleep_ms(ms: u64) {
    std::thread::sleep(std::time::Duration::from_millis(ms.min(MAX_DELAY_MS)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_grammar_and_rejects_junk() {
        let plan =
            FaultPlan::parse("seed:7;client.request:drop:0.5;transport.read:delay:1.0:5")
                .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.rules.len(), 2);
        assert_eq!(
            plan.rules["transport.read"].action,
            FaultAction::Delay(5)
        );
        // empty spec is a valid no-op plan
        assert!(FaultPlan::parse("").unwrap().rules.is_empty());
        for bad in [
            "seed:x",
            "client.request:drop",
            "client.request:explode:0.5",
            "client.request:drop:1.5",
            "client.request:drop:-0.1",
            "client.request:delay:0.5",
            "client.request:drop:0.5:9",
            "a:drop:0.1;a:dup:0.2",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn decisions_are_a_pure_function_of_seed_point_and_hit_index() {
        let spec = "seed:42;a.x:drop:0.3;b.y:delay:0.7:2";
        let (p1, p2) = (FaultPlan::parse(spec).unwrap(), FaultPlan::parse(spec).unwrap());
        let mut fired = 0;
        for i in 0..500 {
            let point = if i % 3 == 0 { "a.x" } else { "b.y" };
            let (d1, d2) = (p1.decide(point), p2.decide(point));
            assert_eq!(d1, d2, "hit {i} at {point} diverged");
            fired += d1.is_some() as u32;
        }
        assert!(fired > 0, "a 0.3/0.7 plan over 500 hits must fire");
        assert_eq!(p1.schedule_digest(), p2.schedule_digest());
        // and a different seed gives a different schedule
        let p3 = FaultPlan::parse("seed:43;a.x:drop:0.3;b.y:delay:0.7:2").unwrap();
        let mut diverged = false;
        for i in 0..500 {
            let point = if i % 3 == 0 { "a.x" } else { "b.y" };
            diverged |= p3.decide(point) != p1.decide(point);
        }
        // (the re-decides above advanced p1's counters too; only the
        // cross-seed divergence is asserted)
        assert!(diverged, "seed must matter");
    }

    #[test]
    fn probability_edges_never_and_always_fire() {
        let plan = FaultPlan::parse("never:drop:0.0;always:dup:1.0").unwrap();
        for _ in 0..200 {
            assert_eq!(plan.decide("never"), None);
            assert_eq!(plan.decide("always"), Some(FaultAction::Dup));
            assert_eq!(plan.decide("unruled.point"), None);
        }
        assert_eq!(plan.point_counts("never"), (200, 0));
        assert_eq!(plan.point_counts("always"), (200, 200));
        assert_eq!(plan.point_counts("unruled.point"), (0, 0));
    }

    #[test]
    fn firing_rate_tracks_probability() {
        let plan = FaultPlan::parse("seed:1;p:drop:0.25").unwrap();
        for _ in 0..4000 {
            plan.decide("p");
        }
        let (hits, fired) = plan.point_counts("p");
        assert_eq!(hits, 4000);
        let rate = fired as f64 / hits as f64;
        assert!((rate - 0.25).abs() < 0.05, "rate {rate} far from 0.25");
    }

    #[test]
    fn global_install_and_clear() {
        // note: other tests in this *module* don't touch the global
        // plan, and nothing outside a chaos-owned process installs one
        install(Some(FaultPlan::parse("g.p:drop:1.0").unwrap()));
        assert_eq!(hit("g.p"), Some(FaultAction::Drop));
        assert_eq!(hit("g.other"), None);
        assert!(global_digest().is_some());
        install(None);
        assert_eq!(hit("g.p"), None);
        assert!(global_digest().is_none());
    }

    #[test]
    fn delay_is_capped() {
        let plan = FaultPlan::parse("p:delay:1.0:99999999").unwrap();
        assert_eq!(plan.decide("p"), Some(FaultAction::Delay(MAX_DELAY_MS)));
    }
}
