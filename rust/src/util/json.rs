//! Minimal JSON parser/serializer (no `serde` available offline).
//!
//! Supports the full JSON grammar we produce and consume: objects, arrays,
//! strings (with escapes), f64 numbers, booleans, null. Used for the AOT
//! `manifest.json` / `golden.json`, experiment configs and results files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Maximum container nesting the parser accepts. The parser is
/// recursive-descent, so unbounded depth is a stack overflow — an
/// *abort*, not an `Err` — on adversarial input like `[[[[…`; 128 is far
/// beyond anything the crate writes (snapshot envelopes nest < 10).
const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Strict u64 decode. `as_f64()? as u64` silently truncates
    /// fractional values, saturates negatives to 0, and loses precision
    /// above 2^53 — all of which corrupt step counters like
    /// `steps_per_stage` on restore. This accepts only finite,
    /// non-negative, integer-valued numbers up to 2^53 (the largest
    /// span where every integer has an exact f64 representation) and
    /// returns `None` for everything else so callers can fail loudly.
    pub fn as_u64_strict(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        let n = self.as_f64()?;
        if !n.is_finite() || n < 0.0 || n != n.trunc() || n > MAX_EXACT {
            return None;
        }
        Some(n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `v.get("a")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Convenience: numeric array -> Vec<f32>.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn arr_f32(values: &[f32]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no inf/nan; clamp like most encoders refuse — we encode
        // as null to keep files loadable.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{}", n);
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, ch: u8) -> Result<(), JsonError> {
        if self.peek() == Some(ch) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", ch as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    /// Four hex digits starting at byte `start` (used by \u escapes).
    fn hex4(&self, start: usize) -> Result<u32, JsonError> {
        if start + 4 > self.bytes.len() {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[start..start + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            // self.pos is at 'u'; 4 hex digits follow
                            let code = self.hex4(self.pos + 1)?;
                            self.pos += 4;
                            if (0xD800..=0xDBFF).contains(&code) {
                                // high surrogate: standard encoders write
                                // non-BMP characters as \uD8xx\uDCxx
                                // pairs — consume the low half and
                                // combine, instead of mangling both into
                                // replacement characters.
                                if self.bytes.get(self.pos + 1) != Some(&b'\\')
                                    || self.bytes.get(self.pos + 2) != Some(&b'u')
                                {
                                    return Err(self.err(
                                        "unpaired surrogate in \\u escape",
                                    ));
                                }
                                let lo = self.hex4(self.pos + 3)?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    return Err(self.err(
                                        "unpaired surrogate in \\u escape",
                                    ));
                                }
                                let combined = 0x10000
                                    + ((code - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(combined)
                                        .expect("combined surrogates are valid"),
                                );
                                self.pos += 6; // the \uXXXX of the low half
                            } else if (0xDC00..=0xDFFF).contains(&code) {
                                return Err(
                                    self.err("unpaired surrogate in \\u escape")
                                );
                            } else {
                                out.push(
                                    char::from_u32(code)
                                        .expect("non-surrogate BMP scalar"),
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"ccn","nums":[1,2.5,-3],"flag":true,"sub":{"k":"v \" q"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn unicode_escape_and_passthrough() {
        let v = Json::parse("\"\\u0041π\"").unwrap();
        assert_eq!(v.as_str(), Some("Aπ"));
        let round = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn surrogate_pairs_decode_to_one_scalar() {
        // the shape every ensure_ascii encoder writes for non-BMP chars
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("😀"));
        // first and last scalars of the supplementary planes
        assert_eq!(
            Json::parse("\"\\ud800\\udc00\"").unwrap().as_str(),
            Some("\u{10000}")
        );
        assert_eq!(
            Json::parse("\"\\udbff\\udfff\"").unwrap().as_str(),
            Some("\u{10FFFF}")
        );
        // mixed with raw text and uppercase hex
        assert_eq!(
            Json::parse("\"a\\uD83D\\uDE00b\"").unwrap().as_str(),
            Some("a😀b")
        );
    }

    #[test]
    fn lone_surrogates_are_rejected_not_mangled() {
        // pre-fix these silently decoded to replacement characters,
        // making parse(write(s)) != s for externally produced files
        for bad in [
            "\"\\ud800\"",        // lone high at end
            "\"\\ud800x\"",       // high followed by raw char
            "\"\\ud800\\u0041\"", // high followed by non-surrogate escape
            "\"\\udc00\"",        // lone low
            "\"\\ude00\\ud83d\"", // reversed pair
            "\"\\u12\"",          // truncated escape
        ] {
            assert!(Json::parse(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn prop_adversarial_strings_roundtrip() {
        use crate::util::check::check;
        // pools chosen to hit every escaping path: controls the writer
        // must \u-encode, the named escapes, the quote/backslash pair,
        // BMP text, and non-BMP scalars the parser must reassemble
        const POOL: &[char] = &[
            '\u{0000}', '\u{0001}', '\u{0008}', '\u{000C}', '\u{001F}',
            '\n', '\r', '\t', '"', '\\', '/', ' ', 'a', 'Z', '0',
            'π', 'ß', '\u{2028}', '\u{FFFD}', '\u{FFFF}',
            '😀', '\u{10000}', '\u{10FFFF}', '𝕊',
        ];
        check("json adversarial string roundtrip", 60, |g| {
            let len = g.sized_usize(0, 40);
            let s: String = (0..len)
                .map(|_| POOL[g.usize_in(0, POOL.len() - 1)])
                .collect();
            // exercise strings as values, as object keys, and nested
            let v = Json::obj(vec![
                ("s", Json::Str(s.clone())),
                (
                    "nested",
                    Json::Arr(vec![Json::Str(s.clone()), Json::Num(1.5)]),
                ),
            ]);
            let v = match v {
                Json::Obj(mut o) => {
                    o.insert(s.clone(), Json::Bool(true));
                    Json::Obj(o)
                }
                _ => unreachable!(),
            };
            let compact = Json::parse(&v.dump())
                .map_err(|e| format!("compact reparse: {e} (s = {s:?})"))?;
            if compact != v {
                return Err(format!("compact roundtrip mutated {s:?}"));
            }
            let pretty = Json::parse(&v.pretty())
                .map_err(|e| format!("pretty reparse: {e} (s = {s:?})"))?;
            if pretty != v {
                return Err(format!("pretty roundtrip mutated {s:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // an adversarial client can send megabytes of "[[[["; the parser
        // must return Err, not blow the thread stack (an abort)
        for (open, close) in [("[", "]"), (r#"{"k":"#, "}")] {
            let deep =
                open.repeat(100_000) + "null" + &close.repeat(100_000);
            let err = Json::parse(&deep).unwrap_err();
            assert!(err.msg.contains("nesting"), "{}", err.msg);
        }
        // unterminated nesting bombs die the same way
        assert!(Json::parse(&"[".repeat(1_000_000)).is_err());
        // realistic depth stays fine
        let ok = "[".repeat(64) + "1" + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn u64_strict_boundaries() {
        let ok = |t: &str, want: u64| {
            assert_eq!(
                Json::parse(t).unwrap().as_u64_strict(),
                Some(want),
                "{t} must decode"
            );
        };
        let bad = |t: &str| {
            assert_eq!(
                Json::parse(t).unwrap().as_u64_strict(),
                None,
                "{t} must be rejected"
            );
        };
        ok("0", 0);
        ok("1", 1);
        ok("100000", 100_000);
        // 2^53: the last exactly representable integer — accepted
        ok("9007199254740992", 9_007_199_254_740_992);
        bad("1.5"); // fractional: was silently truncated to 1
        bad("-1"); // negative: was saturated to 0
        bad("-0.5");
        bad("1e16"); // above 2^53: f64 cannot hold it exactly
        bad("null");
        bad("\"7\"");
        bad("true");
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.0, 2.5, 3.0]);
    }

    #[test]
    fn nonfinite_encodes_as_null() {
        let v = Json::Num(f64::NAN);
        assert_eq!(v.dump(), "null");
    }

    #[test]
    fn obj_helper_and_get() {
        let v = Json::obj(vec![
            ("steps", Json::Num(100.0)),
            ("name", Json::Str("fig4".into())),
        ]);
        assert_eq!(v.get("steps").unwrap().as_usize(), Some(100));
        assert_eq!(v.get("name").unwrap().as_str(), Some("fig4"));
        assert!(v.get("missing").is_none());
    }
}
