//! Substrate utilities the framework is built on.
//!
//! Everything here is hand-rolled because the build is fully offline:
//! deterministic PRNGs ([`prng`]), a JSON codec ([`json`]), a CLI argument
//! parser ([`cli`]), a mini property-testing framework ([`check`]) and a
//! seeded fault-injection plan ([`fault`]) for reproducible chaos.

pub mod check;
pub mod cli;
pub mod fault;
pub mod json;
pub mod prng;

/// Dot product — the single most executed routine in the repo; kept here
/// so every net shares one optimized implementation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane manual unroll: autovectorizes cleanly with -O3 and avoids the
    // sequential-FP-add dependency chain.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut rest = 0.0f32;
    for i in chunks * 4..n {
        rest += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + rest
}

/// `y += alpha * x` (axpy), same unrolling rationale as [`dot`].
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..23).map(|i| i as f32 * 0.3 - 2.0).collect();
        let b: Vec<f32> = (0..23).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn dot_empty_and_short() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn axpy_accumulates() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 10.0, 10.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, vec![10.5, 11.0, 11.5]);
    }

    #[test]
    fn sigmoid_range_and_symmetry() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(30.0) > 0.999_999);
        assert!(sigmoid(-30.0) < 1e-6);
        let s = sigmoid(1.3) + sigmoid(-1.3);
        assert!((s - 1.0).abs() < 1e-6);
    }
}
