//! Deterministic PRNGs for experiments (no `rand` crate offline).
//!
//! - [`SplitMix64`]: seeding / stream-splitting generator.
//! - [`Xoshiro256`]: xoshiro256** — the workhorse for environments and
//!   initializers. Every experiment component derives its own independent
//!   stream via [`Xoshiro256::split`], so adding a learner never perturbs
//!   an environment's randomness (important for seed-paired comparisons).

/// SplitMix64: tiny, solid generator used to seed xoshiro streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna). Public-domain algorithm.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // all-zero state is invalid; splitmix cannot produce 4 zeros from
        // any seed, but be defensive anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent child stream (hash current output).
    pub fn split(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }

    /// Raw generator state, for snapshot/restore of long-lived sessions.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a previously captured [`Self::state`].
    ///
    /// The all-zero state is xoshiro's single degenerate fixed point (it
    /// would emit zeros forever). Since snapshots travel through JSON,
    /// a corrupted or hand-built snapshot can present it; we map it to
    /// the canonical reseed `seed_from_u64(0)` rather than returning a
    /// dead generator. No state captured from a live generator is ever
    /// all-zero, so the remap never changes a legitimate restore.
    pub fn from_state(s: [u64; 4]) -> Self {
        if s == [0, 0, 0, 0] {
            return Self::seed_from_u64(0);
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; the experiment hot paths use uniforms, not normals).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_continues_identically() {
        let mut a = Xoshiro256::seed_from_u64(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Xoshiro256::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn from_state_all_zero_reseeds_canonically() {
        // regression: an all-zero state would be a fixed point emitting
        // zeros forever; from_state must remap it to the canonical
        // seed_from_u64(0) stream.
        let mut z = Xoshiro256::from_state([0, 0, 0, 0]);
        assert_ne!(z.state(), [0, 0, 0, 0], "degenerate state must not survive");
        let mut canon = Xoshiro256::seed_from_u64(0);
        let mut saw_nonzero = false;
        for _ in 0..100 {
            let v = z.next_u64();
            assert_eq!(v, canon.next_u64(), "remap must be the canonical reseed");
            saw_nonzero |= v != 0;
        }
        assert!(saw_nonzero, "generator must actually produce entropy");
        // and a nonzero state passes through untouched
        let live = Xoshiro256::seed_from_u64(5).state();
        assert_eq!(Xoshiro256::from_state(live).state(), live);
    }

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the canonical C impl.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::seed_from_u64(43);
        let same = (0..100).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn split_streams_are_independent_of_parent_consumption() {
        let mut parent1 = Xoshiro256::seed_from_u64(7);
        let child1 = parent1.split();
        let mut parent2 = Xoshiro256::seed_from_u64(7);
        let child2 = parent2.split();
        let mut c1 = child1.clone();
        let mut c2 = child2.clone();
        for _ in 0..50 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut sum = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!(
                (c as i64 - expect as i64).abs() < (expect as i64) / 10,
                "count {c} vs {expect}"
            );
        }
    }

    #[test]
    fn int_in_inclusive_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = rng.int_in(14, 26);
            assert!((14..=26).contains(&v));
            lo_seen |= v == 14;
            hi_seen |= v == 26;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn choose_indices_distinct_and_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..100 {
            let v = rng.choose_indices(20, 10);
            assert_eq!(v.len(), 10);
            let mut sorted = v.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10);
            assert!(v.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
