//! Chaos soak: a replicating router over three real `ccn serve`
//! children, each armed with a seeded deterministic [`FaultPlan`]
//! (`CCN_FAULTS`), one of them SIGKILLed mid-load.
//!
//! The contract under test, matching ISSUE/README "Failure model &
//! guarantees":
//!
//! - **No acked loss** — with `replicate_every = 1`, every step the
//!   client saw acked survives the kill: sessions promoted onto their
//!   warm standbys stay bit-exact with a twin that replayed exactly the
//!   acked inputs.
//! - **Fault transparency** — the armed faults (connection-killing read
//!   drops, store/write delays) only ever surface as typed, loud
//!   errors; a blind retry of a provably-unexecuted op keeps lockstep.
//! - **Schedule determinism** — the same seeded spec produces the
//!   identical fault schedule twice, digest and per-hit decisions both.
//!
//! One test in its own binary on purpose: the fault plan is
//! process-global, so sharing a test process would let a parallel test
//! see injected faults it never asked for.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ccn_rtrl::cluster::{ClientConfig, RouterConfig, RouterServer, WireClient};
use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::util::fault::FaultPlan;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

const N: usize = 8;
const KINDS: [&str; 3] = ["columnar:8", "ccn:8:2:100000", "tbptt:4:10"];

/// Provably-not-executed faults only (a dropped *read* kills the
/// connection before the op runs; delays run the op once, late), so the
/// driver may blindly retry an errored op without breaking lockstep
/// with the twin. Write drops / dups would make execution ambiguous —
/// their semantics are covered by unit tests, not this soak.
const FAULT_SPEC: &str =
    "seed:7;transport.read:drop:0.02;store.append:delay:0.3:2;\
     transport.write:delay:0.2:1";

fn fast_cfg() -> ClientConfig {
    ClientConfig {
        connect_timeout: Duration::from_millis(250),
        retries: 1,
        backoff: Duration::from_millis(10),
        ..ClientConfig::default()
    }
}

fn unique_base(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .subsec_nanos();
    std::env::temp_dir().join(format!(
        "ccn-chaos-{tag}-{}-{nanos}",
        std::process::id()
    ))
}

fn spawn_serve(sock: &Path, store: &Path, offset: u64, stride: u64) -> Child {
    Command::new(env!("CARGO_BIN_EXE_ccn"))
        .args([
            "serve".to_string(),
            "--listen".to_string(),
            format!("unix://{}", sock.display()),
            "--store-dir".to_string(),
            store.display().to_string(),
            "--shards".to_string(),
            "1".to_string(),
            "--id-offset".to_string(),
            offset.to_string(),
            "--id-stride".to_string(),
            stride.to_string(),
        ])
        // the children run the seeded chaos schedule; the router and
        // this driver stay clean so every divergence is injected, not
        // incidental
        .env("CCN_FAULTS", FAULT_SPEC)
        // stdin held open: closing it is the child's shutdown signal
        .stdin(Stdio::piped())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn ccn serve")
}

fn wait_ready(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(mut c) = WireClient::dial(addr, fast_cfg()) {
            if c.ping().is_ok() {
                return;
            }
        }
        assert!(
            Instant::now() < deadline,
            "backend {addr} never answered ping"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Step through the router, retrying loudly-errored attempts. Every
/// armed fault and the mid-soak kill are either provably-unexecuted
/// (read drop, connect refusal) or resolved by promotion onto a replica
/// that never saw an un-acked op — so a retry cannot double-step.
fn step_acked(client: &mut WireClient, id: u64, x: &[f32], c: f32) -> f64 {
    let line = format!(
        r#"{{"op":"step","id":{id},"x":{},"c":{c}}}"#,
        Json::arr_f32(x).dump()
    );
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        if let Ok(reply) = client.request_line(&line) {
            if let Ok(v) = Json::parse(&reply) {
                if v.get("ok") == Some(&Json::Bool(true)) {
                    return v
                        .get("y")
                        .and_then(|y| y.as_f64())
                        .expect("acked step carries y");
                }
            }
        }
        assert!(
            Instant::now() < deadline,
            "session {id}: step never acked (failover wedged?)"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn cluster_stat(client: &mut WireClient, key: &str) -> f64 {
    let v = client.request_ok(r#"{"op":"stats"}"#).expect("stats");
    v.get("cluster")
        .and_then(|c| c.get(key))
        .and_then(|n| n.as_f64())
        .unwrap_or_else(|| panic!("stats cluster block has no {key}"))
}

#[test]
fn chaos_soak_with_kill_loses_no_acked_step() {
    // -- schedule determinism: twin plans fire identically ------------
    let plan_a = FaultPlan::parse(FAULT_SPEC).expect("spec parses");
    let plan_b = FaultPlan::parse(FAULT_SPEC).expect("spec parses");
    assert_eq!(plan_a.schedule_digest(), plan_b.schedule_digest());
    let points =
        ["transport.read", "store.append", "transport.write", "unarmed"];
    for i in 0..4000 {
        let p = points[i % points.len()];
        assert_eq!(
            plan_a.decide(p),
            plan_b.decide(p),
            "hit {i} of {p}: the seeded schedule must replay identically"
        );
    }
    let (hits, fired) = plan_a.point_counts("transport.read");
    assert_eq!(hits, 1000);
    assert!(fired > 0, "a 2% drop rule that never fires in 1000 hits");
    assert_eq!(plan_a.point_counts("transport.read"), plan_b.point_counts("transport.read"));

    // -- the fleet: 3 chaos-armed children + a replicating router -----
    let base = unique_base("soak");
    std::fs::create_dir_all(&base).unwrap();
    let socks: Vec<PathBuf> =
        (0..3).map(|k| base.join(format!("b{k}.sock"))).collect();
    let stores: Vec<PathBuf> =
        (0..3).map(|k| base.join(format!("store{k}"))).collect();
    let addrs: Vec<String> = socks
        .iter()
        .map(|s| format!("unix://{}", s.display()))
        .collect();
    let mut children: Vec<Child> = (0..3)
        .map(|k| spawn_serve(&socks[k], &stores[k], k as u64, 3))
        .collect();
    for a in &addrs {
        wait_ready(a);
    }
    let mut cfg = RouterConfig::new(
        addrs.iter().map(|a| ListenAddr::parse(a).unwrap()).collect(),
    );
    cfg.client = fast_cfg();
    cfg.health_interval = Duration::from_millis(100);
    cfg.replicate_every = 1; // zero acked-loss window
    let router = RouterServer::bind(
        cfg,
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
    )
    .expect("bind router");
    let mut client =
        WireClient::dial(router.local_addr(), fast_cfg()).unwrap();

    // the twin replays exactly the acked inputs, fault-free
    let (twin_srv, twin_addr) = {
        let server = Server::bind(
            Service::new(1),
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            0,
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        (server, addr)
    };
    let mut twin = WireClient::dial(&twin_addr, fast_cfg()).unwrap();

    let ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| client.open(kind, N, j as u64).expect("open"))
        .collect();
    let twin_ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| twin.open(kind, N, j as u64).expect("twin open"))
        .collect();

    // deterministic input stream, mirrored tick-by-tick on the twin
    let ticks = 30usize;
    let kill_tick = 10usize;
    let mut rng = Xoshiro256::seed_from_u64(0xc4a0);
    let mut acked_steps = 0u64;
    let mut victim: Option<usize> = None;
    for t in 0..ticks {
        for (j, (&id, &tid)) in ids.iter().zip(&twin_ids).enumerate() {
            let x: Vec<f32> =
                (0..N).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            let y = step_acked(&mut client, id, &x, c);
            let w = twin.step(tid, &x, c).expect("twin step");
            assert_eq!(
                y.to_bits(),
                w.to_bits(),
                "tick {t} session {j}: acked y diverged from the twin"
            );
            acked_steps += 1;
        }
        if t == kill_tick {
            // A ship to a standby can fail under the injected faults
            // without failing the acked op (repl_errors, the documented
            // staleness window); the next acked op re-ships the full
            // snapshot. Drive the fleet until every acked op is on a
            // standby so the kill tests promotion, not failed-ship
            // staleness — this keeps the bit-exact assert deterministic.
            let mut settle = 0;
            while cluster_stat(&mut client, "repl_lag") > 0.0 {
                assert!(settle < 50, "replication lag never drained");
                settle += 1;
                for (&id, &tid) in ids.iter().zip(&twin_ids) {
                    let x: Vec<f32> =
                        (0..N).map(|_| rng.uniform(-1.0, 1.0)).collect();
                    let c = rng.uniform(-0.5, 0.5);
                    let y = step_acked(&mut client, id, &x, c);
                    let w = twin.step(tid, &x, c).expect("twin step");
                    assert_eq!(y.to_bits(), w.to_bits());
                    acked_steps += 1;
                }
            }
            // SIGKILL whichever backend holds session 0 — promotion has
            // real state to save. No flush, no goodbye.
            let b = router
                .router()
                .placement_of(ids[0])
                .expect("session 0 is placed");
            children[b].kill().expect("kill victim");
            children[b].wait().expect("reap victim");
            victim = Some(b);
        }
    }
    assert!(acked_steps >= (ticks * KINDS.len()) as u64);
    let victim = victim.expect("kill happened");

    // the killed backend's sessions were promoted, none failed over to
    // nowhere: every session still answers, still bit-exact
    assert!(
        cluster_stat(&mut client, "promotions") >= 1.0,
        "the kill must have promoted at least session 0"
    );
    // K=1 ships an envelope per acked step, but ships aimed at the
    // just-killed standby fail (without failing the client op) until the
    // next probe re-targets the successor — so assert "most", not "all".
    assert!(
        cluster_stat(&mut client, "replicated") >= acked_steps as f64 * 0.5,
        "K=1 should have shipped an envelope for most acked steps"
    );
    for (j, (&id, &tid)) in ids.iter().zip(&twin_ids).enumerate() {
        assert_ne!(
            router.router().placement_of(id),
            Some(victim),
            "session {j} still pinned to the corpse"
        );
        let state = client
            .snapshot(id)
            .unwrap_or_else(|e| panic!("snapshot session {j}: {e}"));
        let want = twin.snapshot(tid).expect("twin snapshot");
        assert_eq!(
            state, want,
            "session {j}: promoted state != acked-prefix twin replay"
        );
    }

    for mut child in children {
        let _ = child.kill();
        let _ = child.wait();
    }
    router.shutdown().expect("router shutdown");
    twin_srv.shutdown().expect("twin shutdown");
    let _ = std::fs::remove_dir_all(&base);
}
