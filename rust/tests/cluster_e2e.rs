//! Cluster-tier end-to-end suite: a router fronting real backends over
//! real sockets.
//!
//! The contract under test, matching the router's module docs:
//!
//! - **Transparency** — for any op against a single backend the router's
//!   reply is byte-identical to talking to that backend directly,
//!   including every locally-generated error.
//! - **Scale-out** — the same workload over 1, 2, and 4 backends (each
//!   minting its own `--id-offset/--id-stride` residue class) produces
//!   bit-identical predictions, spreads sessions across the fleet, and
//!   accounts every wire step exactly once.
//! - **Live migration** — `handoff` and `drain` racing real step traffic
//!   never perturb a learner: the full y-sequence and the final snapshot
//!   envelopes stay bit-identical to a single-process twin replay.
//! - **Failure** — SIGKILL a real `ccn serve` child mid-soak: parked
//!   sessions survive in its store, the router fails pinned ops loudly
//!   while the backend is down, and after a restart on the same socket
//!   (stale-lock takeover) + store dir (boot scan) every session warms
//!   and matches the twin bit-for-bit.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ccn_rtrl::cluster::{ClientConfig, RouterConfig, RouterServer, WireClient};
use ccn_rtrl::obs::{RegistrySnapshot, TraceConfig};
use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

/// One session per net kind keeps every learner family under migration.
const KINDS: [&str; 4] = ["columnar:8", "ccn:8:2:100000", "tbptt:4:10", "snap1:4"];
const N: usize = 8;

fn fast_cfg() -> ClientConfig {
    ClientConfig {
        connect_timeout: Duration::from_millis(250),
        retries: 1,
        backoff: Duration::from_millis(10),
        ..ClientConfig::default()
    }
}

fn router_cfg(backends: Vec<ListenAddr>) -> RouterConfig {
    let mut cfg = RouterConfig::new(backends);
    cfg.client = fast_cfg();
    cfg.health_interval = Duration::from_millis(100);
    cfg
}

fn tcp_backend(
    shards: usize,
    scheme: Option<(u64, u64)>,
) -> (Server, ListenAddr) {
    let mut service = Service::new(shards);
    if let Some((offset, stride)) = scheme {
        service.set_id_scheme(offset, stride).expect("id scheme");
    }
    let server = Server::bind(
        service,
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let addr = ListenAddr::parse(server.local_addr()).unwrap();
    (server, addr)
}

fn bind_router(backends: Vec<ListenAddr>) -> RouterServer {
    RouterServer::bind(
        router_cfg(backends),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
    )
    .expect("bind router")
}

fn unique_base(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .subsec_nanos();
    std::env::temp_dir().join(format!(
        "ccn-cluster-{tag}-{}-{nanos}",
        std::process::id()
    ))
}

/// `[tick][session] -> (x, c)`: one deterministic input stream.
type Stream = Vec<Vec<(Vec<f32>, f32)>>;

/// Deterministic per-tick, per-session `(x, c)` stream: the same seed
/// replays the identical inputs against a cluster and its twin.
fn stream(seed: u64, ticks: usize, sessions: usize) -> Stream {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..ticks)
        .map(|_| {
            (0..sessions)
                .map(|_| {
                    let x: Vec<f32> =
                        (0..N).map(|_| rng.uniform(-1.0, 1.0)).collect();
                    let c = rng.uniform(-0.5, 0.5);
                    (x, c)
                })
                .collect()
        })
        .collect()
}

/// Send one raw line both through the router and to an identically
/// configured direct backend; the replies must match byte for byte.
fn compare(via: &mut WireClient, direct: &mut WireClient, line: &str) -> String {
    let a = via.request_line(line).expect("router reply");
    let b = direct.request_line(line).expect("direct reply");
    assert_eq!(a, b, "router must be byte-transparent for {line}");
    a
}

fn reply_id(reply: &str) -> u64 {
    Json::parse(reply)
        .ok()
        .and_then(|v| v.get("id").and_then(|n| n.as_f64()))
        .expect("reply id") as u64
}

#[test]
fn router_replies_match_a_direct_backend_byte_for_byte() {
    let base = unique_base("transparent");
    std::fs::create_dir_all(&base).unwrap();

    // twin backends with identical config: one behind the router (over
    // UDS, so both transport kinds are in play), one driven directly
    let sock = base.join("b0.sock");
    let routed = Server::bind(
        Service::new(2),
        &ListenAddr::parse(&format!("unix://{}", sock.display())).unwrap(),
        0,
    )
    .unwrap();
    let (direct_srv, _) = tcp_backend(2, None);
    let router =
        bind_router(vec![ListenAddr::parse(routed.local_addr()).unwrap()]);

    let mut via = WireClient::dial(router.local_addr(), fast_cfg()).unwrap();
    let mut direct =
        WireClient::dial(direct_srv.local_addr(), fast_cfg()).unwrap();

    compare(&mut via, &mut direct, r#"{"op":"ping"}"#);

    let ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| {
            let line = format!(
                r#"{{"op":"open","learner":"{kind}","n_inputs":{N},"seed":{j}}}"#
            );
            reply_id(&compare(&mut via, &mut direct, &line))
        })
        .collect();

    // live traffic: step / predict across every kind
    for tick in &stream(0x7a9, 6, ids.len()) {
        for ((x, c), &id) in tick.iter().zip(&ids) {
            let line = format!(
                r#"{{"op":"step","id":{id},"x":{},"c":{c}}}"#,
                Json::arr_f32(x).dump()
            );
            compare(&mut via, &mut direct, &line);
        }
    }
    let probe = Json::arr_f32(&[0.25f32; N]).dump();
    for &id in &ids {
        let line = format!(r#"{{"op":"predict","id":{id},"x":{probe}}}"#);
        compare(&mut via, &mut direct, &line);
    }

    // a whole-cohort step_batch stays on one backend -> forwarded raw,
    // including the per-item error for a ghost id
    let batch = {
        let ids_json: Vec<String> =
            ids.iter().map(|id| id.to_string()).chain(["9999".into()]).collect();
        let xs: Vec<String> =
            (0..=ids.len()).map(|_| probe.clone()).collect();
        let cs: Vec<String> = (0..=ids.len()).map(|_| "0.1".into()).collect();
        format!(
            r#"{{"op":"step_batch","ids":[{}],"xs":[{}],"cs":[{}]}}"#,
            ids_json.join(","),
            xs.join(","),
            cs.join(",")
        )
    };
    compare(&mut via, &mut direct, &batch);

    // snapshots are deterministic twins; reuse one state for restore
    let mut state = None;
    for &id in &ids {
        let line = format!(r#"{{"op":"snapshot","id":{id}}}"#);
        let reply = compare(&mut via, &mut direct, &line);
        if state.is_none() {
            state = Json::parse(&reply).unwrap().get("state").cloned();
        }
    }
    let state = state.expect("snapshot state").dump();

    // restore-as-id (the migration hook), then a minted restore: the
    // explicit id fences both allocators identically, so the minted ids
    // agree too
    let line = format!(r#"{{"op":"restore","id":4242,"state":{state}}}"#);
    compare(&mut via, &mut direct, &line);
    let line = format!(r#"{{"op":"restore","state":{state}}}"#);
    let minted = reply_id(&compare(&mut via, &mut direct, &line));
    let line = format!(r#"{{"op":"step","id":4242,"x":{probe},"c":0.5}}"#);
    compare(&mut via, &mut direct, &line);

    // error paths reuse the exact serve code, byte for byte
    compare(&mut via, &mut direct, r#"{"op":"step","id":777,"x":[0.0],"c":0.0}"#);
    compare(&mut via, &mut direct, r#"{"op":"flarp"}"#);
    compare(&mut via, &mut direct, r#"{nope"#);
    compare(&mut via, &mut direct, r#"{"op":"park","id":4242}"#);

    for id in ids.iter().copied().chain([4242, minted]) {
        let line = format!(r#"{{"op":"close","id":{id}}}"#);
        compare(&mut via, &mut direct, &line);
    }

    // stats/metrics aggregate by design (not byte-comparable): check the
    // router's own shape instead
    let stats = via.stats().expect("router stats");
    assert!(stats.get("cluster").is_some(), "router stats carries a cluster block");
    let metrics = via.metrics().expect("router metrics");
    assert!(metrics.get("cluster").is_some(), "router metrics carries a cluster block");

    router.shutdown().expect("router shutdown");
    routed.shutdown().expect("routed backend shutdown");
    direct_srv.shutdown().expect("direct backend shutdown");
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn scale_out_1_2_4_is_bit_exact_and_spreads_sessions() {
    let sessions = 8;
    let ticks = 15;
    let inputs = stream(0x5ca1e, ticks, sessions);
    let mut reference: Option<Vec<Vec<u64>>> = None;

    for n_backends in [1usize, 2, 4] {
        let mut servers = Vec::new();
        let mut addrs = Vec::new();
        for k in 0..n_backends {
            let scheme =
                (n_backends > 1).then_some((k as u64, n_backends as u64));
            let (srv, addr) = tcp_backend(1, scheme);
            servers.push(srv);
            addrs.push(addr);
        }
        let router = bind_router(addrs);
        let mut client =
            WireClient::dial(router.local_addr(), fast_cfg()).unwrap();

        let ids: Vec<u64> = (0..sessions)
            .map(|j| {
                client
                    .open(KINDS[j % KINDS.len()], N, j as u64)
                    .expect("open")
            })
            .collect();

        // minted ids must carry the minting backend's residue class
        if n_backends > 1 {
            for &id in &ids {
                let b = router.router().placement_of(id).expect("placed");
                assert_eq!(
                    id % n_backends as u64,
                    b as u64,
                    "id {id} must live in backend {b}'s residue class"
                );
            }
            let spread: BTreeSet<usize> = ids
                .iter()
                .map(|&id| router.router().placement_of(id).unwrap())
                .collect();
            assert!(
                spread.len() >= 2,
                "{n_backends} backends must share the {sessions} sessions, \
                 got {spread:?}"
            );
        }

        let ys: Vec<Vec<u64>> = inputs
            .iter()
            .map(|tick| {
                tick.iter()
                    .zip(&ids)
                    .map(|((x, c), &id)| {
                        client.step(id, x, *c).expect("step").to_bits()
                    })
                    .collect()
            })
            .collect();

        // every wire step lands on exactly one backend
        let served: u64 = servers
            .iter()
            .flat_map(|s| s.service().pool().stats())
            .map(|st| st.steps)
            .sum();
        assert_eq!(served as usize, sessions * ticks);

        match &reference {
            None => reference = Some(ys),
            Some(want) => assert_eq!(
                want, &ys,
                "{n_backends}-backend predictions must be bit-identical \
                 to the single-backend run"
            ),
        }

        router.shutdown().expect("router shutdown");
        for srv in servers {
            srv.shutdown().expect("backend shutdown");
        }
    }
}

#[test]
fn handoff_and_drain_mid_traffic_stay_bit_exact() {
    let base = unique_base("midtraffic");
    std::fs::create_dir_all(&base).unwrap();

    // two backends on disjoint residue classes, mixed transports
    let (b0, a0) = tcp_backend(1, Some((0, 2)));
    let sock = base.join("b1.sock");
    let mut svc1 = Service::new(1);
    svc1.set_id_scheme(1, 2).expect("id scheme");
    let b1 = Server::bind(
        svc1,
        &ListenAddr::parse(&format!("unix://{}", sock.display())).unwrap(),
        0,
    )
    .unwrap();
    let a1 = ListenAddr::parse(b1.local_addr()).unwrap();
    let labels = [a0.to_string(), a1.to_string()];
    let router = bind_router(vec![a0, a1]);

    // the twin: one plain backend replaying the identical input stream
    let (twin_srv, _) = tcp_backend(1, None);
    let mut twin = WireClient::dial(twin_srv.local_addr(), fast_cfg()).unwrap();
    let mut client = WireClient::dial(router.local_addr(), fast_cfg()).unwrap();

    let sessions = KINDS.len();
    let ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| client.open(kind, N, j as u64).expect("open"))
        .collect();
    let twin_ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| twin.open(kind, N, j as u64).expect("twin open"))
        .collect();

    let ticks = 30;
    let inputs = stream(0xfeed, ticks, sessions);

    // phase A: an admin thread migrates every session round-robin while
    // the main thread drives step traffic — per-id gates must serialize
    // each move against in-flight ops without perturbing any learner
    let stop = Arc::new(AtomicBool::new(false));
    let admin_stop = Arc::clone(&stop);
    let admin_addr = router.local_addr().to_string();
    let admin_ids = ids.clone();
    let admin = std::thread::spawn(move || -> usize {
        let mut admin =
            WireClient::dial(&admin_addr, fast_cfg()).expect("dial admin");
        let mut moves = 0usize;
        while !admin_stop.load(Ordering::Relaxed) {
            for &id in &admin_ids {
                let line = format!(r#"{{"op":"handoff","id":{id}}}"#);
                let v = admin.request_ok(&line).expect("handoff");
                assert!(v.get("from").is_some() && v.get("to").is_some());
                moves += 1;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        moves
    });

    let mut ys: Vec<Vec<u64>> = Vec::new();
    for tick in &inputs {
        ys.push(
            tick.iter()
                .zip(&ids)
                .map(|((x, c), &id)| {
                    client.step(id, x, *c).expect("step").to_bits()
                })
                .collect(),
        );
    }
    stop.store(true, Ordering::Relaxed);
    let moves = admin.join().expect("admin thread");
    assert!(moves > 0, "the soak must overlap at least one migration");

    // twin replay: the recorded y-sequence must match bit for bit
    for (t, tick) in inputs.iter().enumerate() {
        for (j, ((x, c), &tid)) in tick.iter().zip(&twin_ids).enumerate() {
            let y = twin.step(tid, x, *c).expect("twin step").to_bits();
            assert_eq!(
                ys[t][j], y,
                "tick {t} session {j}: migration must not perturb the learner"
            );
        }
    }

    // phase B: drain whichever backend currently hosts ids[0]
    let victim = router.router().placement_of(ids[0]).expect("placed");
    let line = format!(r#"{{"op":"drain","backend":"{}"}}"#, labels[victim]);
    let v = client.request_ok(&line).expect("drain");
    assert!(
        v.get("moved").and_then(|m| m.as_f64()).unwrap_or(0.0) >= 1.0,
        "drain must migrate the victim's sessions"
    );
    for &id in &ids {
        assert_ne!(
            router.router().placement_of(id),
            Some(victim),
            "drain must leave nothing behind"
        );
    }
    let h = client.request_ok(r#"{"op":"health"}"#).expect("health");
    let backends = h.get("backends").and_then(|b| b.as_arr()).unwrap();
    assert_eq!(backends[victim].get("alive"), Some(&Json::Bool(true)));
    assert_eq!(backends[victim].get("in_ring"), Some(&Json::Bool(false)));

    // traffic continues on the survivor, still bit-exact
    for tick in &stream(0xf00d, 5, sessions) {
        for ((x, c), (&id, &tid)) in
            tick.iter().zip(ids.iter().zip(&twin_ids))
        {
            let y = client.step(id, x, *c).expect("step").to_bits();
            let w = twin.step(tid, x, *c).expect("twin step").to_bits();
            assert_eq!(y, w, "post-drain step must stay bit-exact");
        }
    }

    // rebalance is a no-op error-free pass with the victim out of the ring
    let v = client.request_ok(r#"{"op":"rebalance"}"#).expect("rebalance");
    assert!(v.get("moved").is_some());

    // final states byte-identical to the never-migrated twin
    for (j, (&id, &tid)) in ids.iter().zip(&twin_ids).enumerate() {
        let state = client.snapshot(id).expect("snapshot");
        let want = twin.snapshot(tid).expect("twin snapshot");
        assert_eq!(
            state, want,
            "session {j}: migrated state must equal the twin's bit-for-bit"
        );
    }

    router.shutdown().expect("router shutdown");
    b0.shutdown().expect("b0 shutdown");
    b1.shutdown().expect("b1 shutdown");
    twin_srv.shutdown().expect("twin shutdown");
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn fleet_scope_metrics_equal_the_offline_merge_of_backend_blocks() {
    let (b0, a0) = tcp_backend(1, Some((0, 2)));
    let (b1, a1) = tcp_backend(1, Some((1, 2)));
    let router = bind_router(vec![a0, a1]);
    let mut client = WireClient::dial(router.local_addr(), fast_cfg()).unwrap();

    let sessions = 8;
    let ids: Vec<u64> = (0..sessions)
        .map(|j| {
            client
                .open(KINDS[j % KINDS.len()], N, j as u64)
                .expect("open")
        })
        .collect();
    let ticks = 10;
    for tick in &stream(0x0b5e, ticks, sessions) {
        for ((x, c), &id) in tick.iter().zip(&ids) {
            client.step(id, x, *c).expect("step");
        }
    }

    let v = client
        .request_ok(r#"{"op":"metrics","scope":"fleet"}"#)
        .expect("fleet metrics");
    assert_eq!(v.get("scope").and_then(|s| s.as_str()), Some("fleet"));
    let merged = v.get("merged").expect("fleet reply carries a merged block");
    let backends = v
        .get("backends")
        .and_then(|b| b.as_arr())
        .expect("fleet reply carries per-backend blocks");
    assert_eq!(backends.len(), 2);

    // the router's merge must equal an offline merge of the per-backend
    // blocks embedded in the very same reply — same registries, no race
    let mut offline = RegistrySnapshot::default();
    for b in backends {
        assert_eq!(b.get("alive"), Some(&Json::Bool(true)), "{b:?}");
        let m = b.get("metrics").expect("per-backend metrics block");
        let snap =
            RegistrySnapshot::from_metrics_json(m).expect("parse backend block");
        offline = offline.merge(&snap);
    }
    assert_eq!(
        offline.to_json().dump(),
        merged.dump(),
        "fleet merge must equal the offline merge of the embedded blocks"
    );

    // deterministic accounting: every wire step shows up in exactly one
    // backend's histogram, and the merge preserves the total
    let step_count = |m: &Json| -> f64 {
        m.get("ops")
            .and_then(|o| o.get("step"))
            .and_then(|h| h.get("count"))
            .and_then(|c| c.as_f64())
            .unwrap_or(0.0)
    };
    let total: f64 = backends
        .iter()
        .map(|b| step_count(b.get("metrics").unwrap()))
        .sum();
    assert_eq!(total as usize, sessions * ticks);
    assert_eq!(step_count(merged) as usize, sessions * ticks);
    for b in backends {
        assert!(
            step_count(b.get("metrics").unwrap()) >= 1.0,
            "both backends served a share of the steps: {b:?}"
        );
    }
    // the router's own registry rides along, untangled from the fleet's
    assert!(
        v.get("router").and_then(|r| r.get("ops")).is_some(),
        "fleet reply carries the router's own registry"
    );

    router.shutdown().expect("router shutdown");
    b0.shutdown().expect("b0 shutdown");
    b1.shutdown().expect("b1 shutdown");
}

#[test]
fn traced_fleet_is_byte_identical_and_trace_files_join_on_trace_id() {
    let base = unique_base("traced");
    std::fs::create_dir_all(&base).unwrap();
    let router_trace = base.join("router.jsonl");
    let backend_trace = base.join("backend.jsonl");

    // traced pair: router and backend each sample every op
    let mut svc = Service::new(1);
    svc.set_trace(&TraceConfig { path: backend_trace.clone(), sample: 1 })
        .expect("mount backend trace");
    let b_traced = Server::bind(
        svc,
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let mut cfg =
        router_cfg(vec![ListenAddr::parse(b_traced.local_addr()).unwrap()]);
    cfg.trace = Some(TraceConfig { path: router_trace.clone(), sample: 1 });
    let traced_router = RouterServer::bind(
        cfg,
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
    )
    .expect("bind traced router");

    // untraced twin pair, identically configured otherwise
    let (b_plain, a_plain) = tcp_backend(1, None);
    let plain_router = bind_router(vec![a_plain]);

    let mut via_t =
        WireClient::dial(traced_router.local_addr(), fast_cfg()).unwrap();
    let mut via_p =
        WireClient::dial(plain_router.local_addr(), fast_cfg()).unwrap();

    let mut n_ops = 0usize;
    let mut run = |line: &str| -> String {
        n_ops += 1;
        let a = via_t.request_line(line).expect("traced reply");
        let b = via_p.request_line(line).expect("plain reply");
        assert_eq!(a, b, "tracing must not change a single reply byte: {line}");
        a
    };
    let ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| {
            reply_id(&run(&format!(
                r#"{{"op":"open","learner":"{kind}","n_inputs":{N},"seed":{j}}}"#
            )))
        })
        .collect();
    for tick in &stream(0x70ace, 8, ids.len()) {
        for ((x, c), &id) in tick.iter().zip(&ids) {
            run(&format!(
                r#"{{"op":"step","id":{id},"x":{},"c":{c}}}"#,
                Json::arr_f32(x).dump()
            ));
        }
    }
    // a client-supplied trace id must thread through both hops untouched
    let line = format!(
        r#"{{"op":"snapshot","id":{},"trace_id":"e2e-client-0001"}}"#,
        ids[0]
    );
    run(&line);
    for &id in &ids {
        run(&format!(r#"{{"op":"close","id":{id}}}"#));
    }
    drop(run);

    traced_router.shutdown().expect("traced router shutdown");
    plain_router.shutdown().expect("plain router shutdown");
    b_traced.shutdown().expect("traced backend shutdown");
    b_plain.shutdown().expect("plain backend shutdown");

    let parse_events = |path: &Path| -> Vec<Json> {
        std::fs::read_to_string(path)
            .expect("trace file")
            .lines()
            .map(|l| Json::parse(l).expect("trace event must be valid json"))
            .collect()
    };
    let router_evs = parse_events(&router_trace);
    let backend_evs = parse_events(&backend_trace);
    assert_eq!(router_evs.len(), n_ops, "router samples every protocol op");

    // the backend trace also carries uncorrelated health-probe pings;
    // every *correlated* event is one forwarded protocol op
    let correlated: Vec<&Json> = backend_evs
        .iter()
        .filter(|e| e.get("trace_id").is_some())
        .collect();
    assert_eq!(
        correlated.len(),
        n_ops,
        "backend samples every forwarded op with its correlation fields"
    );
    let mut by_trace: BTreeMap<String, &Json> = BTreeMap::new();
    for ev in correlated {
        let tid = ev
            .get("trace_id")
            .and_then(|t| t.as_str())
            .expect("trace_id is a string")
            .to_string();
        assert!(
            by_trace.insert(tid, ev).is_none(),
            "one backend event per trace"
        );
    }

    // join on trace_id: every router span has exactly one backend child
    // whose parent_span_id is the router's span
    for ev in &router_evs {
        let tid = ev
            .get("trace_id")
            .and_then(|t| t.as_str())
            .expect("router event trace_id");
        let span = ev
            .get("span_id")
            .and_then(|s| s.as_str())
            .expect("router event span_id");
        let child = by_trace
            .get(tid)
            .unwrap_or_else(|| panic!("no backend event for trace {tid}"));
        assert_eq!(
            child.get("parent_span_id").and_then(|p| p.as_str()),
            Some(span),
            "backend event must carry the router's span as its parent"
        );
        assert_ne!(
            child.get("span_id").and_then(|s| s.as_str()),
            Some(span),
            "the backend mints its own span"
        );
    }
    // the client-supplied id survived both hops and names the right op
    let snap_ev = router_evs
        .iter()
        .find(|e| {
            e.get("trace_id").and_then(|t| t.as_str())
                == Some("e2e-client-0001")
        })
        .expect("router event for the client-supplied trace id");
    assert_eq!(snap_ev.get("op").and_then(|o| o.as_str()), Some("snapshot"));
    assert!(by_trace.contains_key("e2e-client-0001"));

    let _ = std::fs::remove_dir_all(&base);
}

fn spawn_serve(sock: &Path, store: &Path, offset: u64, stride: u64) -> Child {
    Command::new(env!("CARGO_BIN_EXE_ccn"))
        .args([
            "serve".to_string(),
            "--listen".to_string(),
            format!("unix://{}", sock.display()),
            "--store-dir".to_string(),
            store.display().to_string(),
            "--shards".to_string(),
            "1".to_string(),
            "--id-offset".to_string(),
            offset.to_string(),
            "--id-stride".to_string(),
            stride.to_string(),
        ])
        // stdin held open: closing it is the child's shutdown signal
        .stdin(Stdio::piped())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn ccn serve")
}

fn wait_ready(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(mut c) = WireClient::dial(addr, fast_cfg()) {
            if c.ping().is_ok() {
                return;
            }
        }
        assert!(
            Instant::now() < deadline,
            "backend {addr} never answered ping"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Poll `health` until backend `idx` reports `alive == want`.
fn wait_alive(client: &mut WireClient, idx: usize, want: bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let h = client.request_ok(r#"{"op":"health"}"#).expect("health");
        let backends = h.get("backends").and_then(|b| b.as_arr()).unwrap();
        if backends[idx].get("alive") == Some(&Json::Bool(want)) {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "backend {idx} never reached alive={want}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn kill_mid_soak_loses_nothing_parked() {
    let base = unique_base("kill");
    std::fs::create_dir_all(&base).unwrap();
    let socks = [base.join("b0.sock"), base.join("b1.sock")];
    let stores = [base.join("store0"), base.join("store1")];
    let addrs: Vec<String> = socks
        .iter()
        .map(|s| format!("unix://{}", s.display()))
        .collect();

    // two real `ccn serve` processes, disjoint residue classes, each
    // with its own durable store
    let mut children: Vec<Child> = (0..2)
        .map(|k| spawn_serve(&socks[k], &stores[k], k as u64, 2))
        .collect();
    for a in &addrs {
        wait_ready(a);
    }

    let listen: Vec<ListenAddr> =
        addrs.iter().map(|a| ListenAddr::parse(a).unwrap()).collect();
    let router = bind_router(listen);
    let mut client = WireClient::dial(router.local_addr(), fast_cfg()).unwrap();

    let (twin_srv, _) = tcp_backend(1, None);
    let mut twin = WireClient::dial(twin_srv.local_addr(), fast_cfg()).unwrap();

    let sessions = KINDS.len();
    let ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| client.open(kind, N, j as u64).expect("open"))
        .collect();
    let twin_ids: Vec<u64> = KINDS
        .iter()
        .enumerate()
        .map(|(j, kind)| twin.open(kind, N, j as u64).expect("twin open"))
        .collect();

    // pin sessions alternately onto both backends (explicit-destination
    // handoff), so the kill hits real state
    for (j, &id) in ids.iter().enumerate() {
        let want = &addrs[j % 2];
        let line = format!(r#"{{"op":"handoff","id":{id},"to":"{want}"}}"#);
        let v = client.request_ok(&line).expect("pin handoff");
        assert_eq!(v.get("to").and_then(|t| t.as_str()), Some(want.as_str()));
    }

    // soak, mirrored tick-by-tick on the twin; one more live migration
    // halfway through
    let ticks = 20;
    let inputs = stream(0xdead, ticks, sessions);
    for (t, tick) in inputs.iter().enumerate() {
        for (j, ((x, c), (&id, &tid))) in
            tick.iter().zip(ids.iter().zip(&twin_ids)).enumerate()
        {
            let y = client.step(id, x, *c).expect("step");
            let w = twin.step(tid, x, *c).expect("twin step");
            assert_eq!(y.to_bits(), w.to_bits(), "tick {t} session {j}");
        }
        if t == ticks / 2 {
            let from = router.router().placement_of(ids[0]).expect("placed");
            let line = format!(
                r#"{{"op":"handoff","id":{},"to":"{}"}}"#,
                ids[0],
                addrs[1 - from]
            );
            client.request_ok(&line).expect("mid-soak handoff");
        }
    }

    // park everything: the durable tier owns every session now
    for &id in &ids {
        client.park(id).expect("park");
    }

    // SIGKILL backend 0 — no flush, no goodbye
    children[0].kill().expect("kill b0");
    children[0].wait().expect("reap b0");
    wait_alive(&mut client, 0, false);

    // pinned ops fail loudly while the home is down; no silent reroute
    let dead_id = ids
        .iter()
        .find(|&&id| router.router().placement_of(id) == Some(0))
        .copied()
        .expect("a session pinned to b0");
    let probe = Json::arr_f32(&[0.0f32; N]).dump();
    let line = format!(r#"{{"op":"step","id":{dead_id},"x":{probe},"c":0.0}}"#);
    let reply = client.request_line(&line).expect("wire");
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    assert!(
        v.get("error")
            .and_then(|e| e.as_str())
            .is_some_and(|m| m.contains("unreachable")),
        "a dead pinned backend must be named, got {reply}"
    );

    // restart on the same socket (stale file + a lock held by a dead
    // pid: the takeover path) and the same store dir (boot scan)
    children[0] = spawn_serve(&socks[0], &stores[0], 0, 2);
    wait_ready(&addrs[0]);
    wait_alive(&mut client, 0, true);
    let h = client.request_ok(r#"{"op":"health"}"#).expect("health");
    let backends = h.get("backends").and_then(|b| b.as_arr()).unwrap();
    assert_eq!(
        backends[0].get("in_ring"),
        Some(&Json::Bool(true)),
        "a revived backend rejoins the ring"
    );

    // every session — the killed backend's parked ones and the migrated
    // one included — warms and matches the twin bit-for-bit
    for (j, (&id, &tid)) in ids.iter().zip(&twin_ids).enumerate() {
        client
            .warm(id)
            .unwrap_or_else(|e| panic!("warm session {j}: {e}"));
        let state = client
            .snapshot(id)
            .unwrap_or_else(|e| panic!("snapshot session {j}: {e}"));
        let want = twin.snapshot(tid).expect("twin snapshot");
        assert_eq!(
            state, want,
            "session {j} must survive the kill bit-exactly"
        );
    }

    // and they keep learning, still in lockstep with the twin
    for tick in &stream(0xbeef, 3, sessions) {
        for ((x, c), (&id, &tid)) in
            tick.iter().zip(ids.iter().zip(&twin_ids))
        {
            let y = client.step(id, x, *c).expect("step").to_bits();
            let w = twin.step(tid, x, *c).expect("twin step").to_bits();
            assert_eq!(y, w, "post-revival step must stay bit-exact");
        }
    }

    for mut child in children {
        let _ = child.kill();
        let _ = child.wait();
    }
    router.shutdown().expect("router shutdown");
    twin_srv.shutdown().expect("twin shutdown");
    let _ = std::fs::remove_dir_all(&base);
}
