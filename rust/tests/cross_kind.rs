//! Cross-kind negative tests: mismatched, forged and corrupted snapshot
//! envelopes must fail with a structured error — and the shard must keep
//! serving as if nothing happened.
//!
//! Covered: restoring a v2 envelope whose `kind` tag belongs to a
//! different family than its `spec`; warming an id whose *parked*
//! envelope was written under a different kind than its spec claims;
//! v1-shim envelopes with corrupted or dense-baseline specs; and a v2
//! envelope whose net payload is garbage.

use ccn_rtrl::config::LearnerKind;
use ccn_rtrl::learn::TdConfig;
use ccn_rtrl::serve::protocol::{Request, Response};
use ccn_rtrl::serve::{Service, Session, SessionSpec, ShardState};
use ccn_rtrl::store::SessionStore;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

fn ok(reply: &str) -> Json {
    let v = Json::parse(reply).expect("response must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(true)),
        "expected ok response, got: {reply}"
    );
    v
}

fn err(reply: &str) -> String {
    let v = Json::parse(reply).expect("response must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(false)),
        "expected error response, got: {reply}"
    );
    v.get("error").and_then(|e| e.as_str()).unwrap().to_string()
}

fn spec_of(learner: LearnerKind, seed: u64) -> SessionSpec {
    SessionSpec {
        learner,
        n_inputs: 3,
        td: TdConfig {
            alpha: 0.01,
            gamma: 0.9,
            lambda: 0.9,
        },
        eps: 0.01,
        seed,
    }
}

/// A driven session's v2 envelope, as `Json`.
fn envelope_of(learner: LearnerKind, seed: u64, steps: usize) -> Json {
    let mut s = Session::open(spec_of(learner, seed)).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5eed);
    for _ in 0..steps {
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        s.step(&x, 0.1).unwrap();
    }
    s.snapshot()
}

fn mutate(
    envelope: &Json,
    f: impl FnOnce(&mut std::collections::BTreeMap<String, Json>),
) -> Json {
    match envelope.clone() {
        Json::Obj(mut o) => {
            f(&mut o);
            Json::Obj(o)
        }
        other => panic!("envelope must be an object, got {other:?}"),
    }
}

fn restore_line(state: &Json) -> String {
    Json::obj(vec![("op", Json::Str("restore".into())), ("state", state.clone())])
        .dump()
}

/// After each rejected restore the service must still open, step and
/// answer stats — the error was the session's, never the shard's.
fn assert_still_serving(service: &Service, expect_sessions: f64) {
    let id = ok(&service.handle_line(
        r#"{"op":"open","learner":"columnar:4","n_inputs":3,"seed":99}"#,
    ))
    .get("id")
    .unwrap()
    .as_f64()
    .unwrap() as u64;
    let y = ok(&service.handle_line(&format!(
        r#"{{"op":"step","id":{id},"x":[0.1,0.2,0.3],"c":0.5}}"#
    )))
    .get("y")
    .unwrap()
    .as_f64()
    .unwrap();
    assert!(y.is_finite());
    ok(&service.handle_line(&format!(r#"{{"op":"close","id":{id}}}"#)));
    let stats = ok(&service.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(stats.get("sessions"), Some(&Json::Num(expect_sessions)));
}

#[test]
fn restore_rejects_kind_spec_family_mismatch_over_the_wire() {
    let service = Service::new(2);
    // a tbptt envelope whose kind tag is forged to the columnar family
    let envelope = envelope_of(LearnerKind::Tbptt { d: 3, k: 5 }, 1, 40);
    let forged = mutate(&envelope, |o| {
        o.insert("kind".into(), Json::Str("columnar".into()));
    });
    let msg = err(&service.handle_line(&restore_line(&forged)));
    assert!(msg.contains("does not match"), "{msg}");
    // and the symmetric forgery: columnar envelope, snap1 kind tag
    let envelope = envelope_of(LearnerKind::Columnar { d: 4 }, 2, 40);
    let forged = mutate(&envelope, |o| {
        o.insert("kind".into(), Json::Str("snap1".into()));
    });
    let msg = err(&service.handle_line(&restore_line(&forged)));
    assert!(msg.contains("does not match"), "{msg}");
    // unknown kinds name themselves in the error
    let forged = mutate(&envelope, |o| {
        o.insert("kind".into(), Json::Str("hopfield".into()));
    });
    let msg = err(&service.handle_line(&restore_line(&forged)));
    assert!(msg.contains("hopfield") || msg.contains("does not match"), "{msg}");
    assert_still_serving(&service, 0.0);
}

#[test]
fn restore_rejects_corrupted_net_payload_and_keeps_serving() {
    let service = Service::new(1);
    let envelope = envelope_of(LearnerKind::Snap1 { d: 3 }, 3, 30);
    for wreck in [
        mutate(&envelope, |o| {
            o.insert("net".into(), Json::Str("zeroed".into()));
        }),
        mutate(&envelope, |o| {
            o.insert("net".into(), Json::obj(vec![("w", Json::Null)]));
        }),
        mutate(&envelope, |o| {
            o.remove("td");
        }),
        mutate(&envelope, |o| {
            o.remove("spec");
        }),
    ] {
        err(&service.handle_line(&restore_line(&wreck)));
    }
    assert_still_serving(&service, 0.0);
}

#[test]
fn v1_shim_rejects_corrupted_and_dense_specs() {
    let service = Service::new(1);
    // v1 envelopes cover the CCN family only: a dense-baseline spec in a
    // v1 wrapper is a forgery, not a migration
    let envelope = envelope_of(LearnerKind::Tbptt { d: 2, k: 4 }, 4, 20);
    let v1_dense = mutate(&envelope, |o| {
        o.insert("v".into(), Json::Num(1.0));
        o.remove("kind");
    });
    let msg = err(&service.handle_line(&restore_line(&v1_dense)));
    assert!(msg.contains("v1"), "{msg}");
    // a v1 envelope whose spec is garbled must fail as a bad spec, not
    // restore with defaults
    let ccn = envelope_of(
        LearnerKind::Ccn {
            total: 4,
            per_stage: 2,
            steps_per_stage: 50,
        },
        5,
        60,
    );
    let v1_broken_spec = mutate(&ccn, |o| {
        o.insert("v".into(), Json::Num(1.0));
        o.remove("kind");
        o.insert(
            "spec".into(),
            Json::obj(vec![("learner", Json::Str("ccn:4:2:50".into()))]),
        );
    });
    let msg = err(&service.handle_line(&restore_line(&v1_broken_spec)));
    assert!(msg.contains("spec"), "{msg}");
    let v1_no_spec = mutate(&ccn, |o| {
        o.insert("v".into(), Json::Num(1.0));
        o.remove("kind");
        o.remove("spec");
    });
    err(&service.handle_line(&restore_line(&v1_no_spec)));
    assert_still_serving(&service, 0.0);
}

/// `warm` of an id whose parked envelope carries a different kind than
/// its spec claims: the rehydration must fail loudly (naming the id),
/// stay failed on retry, and leave the shard fully operational.
#[test]
fn warm_of_id_parked_under_a_different_kind_fails_loudly() {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let dir = std::env::temp_dir().join(format!(
        "ccn-crosskind-{}-{nanos}",
        std::process::id()
    ));
    let mut store = SessionStore::open(&dir).unwrap();
    // a tbptt envelope, re-tagged so the store believes it parks a
    // columnar-family session (a corrupted or forged durable record)
    let envelope = envelope_of(LearnerKind::Tbptt { d: 3, k: 5 }, 7, 25);
    let forged = mutate(&envelope, |o| {
        o.insert("kind".into(), Json::Str("ccn".into()));
    });
    store.park(5, &forged).unwrap();
    // an honest parked neighbor proves the store itself still works
    let honest = envelope_of(LearnerKind::Snap1 { d: 3 }, 8, 25);
    store.park(6, &honest).unwrap();

    let mut shard = ShardState::with_store(Some(store), 0);
    for attempt in 0..2 {
        match shard.handle(Request::Warm { id: 5 }) {
            Response::Error { message, .. } => {
                assert!(
                    message.contains("rehydrate session 5"),
                    "attempt {attempt}: {message}"
                );
                assert!(
                    message.contains("does not match"),
                    "attempt {attempt}: {message}"
                );
            }
            other => panic!("forged warm must fail, got {other:?}"),
        }
    }
    // stepping the forged id fails the same way (step rehydrates too)
    match shard.handle(Request::Step {
        id: 5,
        x: vec![0.1, 0.2, 0.3],
        c: 0.0,
    }) {
        Response::Error { message, .. } => {
            assert!(message.contains("rehydrate"), "{message}")
        }
        other => panic!("forged step must fail, got {other:?}"),
    }
    // the shard still serves: honest parked sessions warm, fresh ones open
    match shard.handle(Request::Warm { id: 6 }) {
        Response::Warmed { rehydrated, .. } => assert!(rehydrated),
        other => panic!("honest warm failed: {other:?}"),
    }
    match shard.handle(Request::Open {
        id: 11,
        spec: spec_of(LearnerKind::Columnar { d: 4 }, 12),
    }) {
        Response::Opened { id } => assert_eq!(id, 11),
        other => panic!("open after forgery failed: {other:?}"),
    }
    match shard.handle(Request::Step {
        id: 11,
        x: vec![0.1, 0.2, 0.3],
        c: 0.1,
    }) {
        Response::Stepped { y } => assert!(y.is_finite()),
        other => panic!("step after forgery failed: {other:?}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
