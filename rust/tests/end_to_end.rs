//! End-to-end learning smoke tests: every learner must reduce prediction
//! error on partially observable streams, and the qualitative orderings
//! the paper reports must hold at small scale.

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::{aggregate_runs, run_experiment, run_sweep, sweep};

fn cfg(
    env: EnvKind,
    learner: LearnerKind,
    alpha: f32,
    steps: u64,
    seed: u64,
) -> ExperimentConfig {
    ExperimentConfig {
        env,
        learner,
        alpha,
        lambda: 0.95,
        gamma_override: None,
        eps: 0.01,
        steps,
        seed,
        curve_points: 20,
    }
}

fn improvement(res: &ccn_rtrl::coordinator::RunResult) -> f64 {
    let early: f64 = res.curve.ys[..3].iter().sum::<f64>() / 3.0;
    let late: f64 =
        res.curve.ys[res.curve.ys.len() - 2..].iter().sum::<f64>() / 2.0;
    early / late.max(1e-12)
}

#[test]
fn every_learner_reduces_error_on_cycle_world() {
    // cycle_world_8 needs 8 steps of memory and is fully learnable —
    // every method achieves a >10x error drop within 120k steps
    // (calibrated: columnar 54x, constructive 32x, ccn 84x, tbptt 121x,
    // snap1 39x).
    let learners = vec![
        LearnerKind::Columnar { d: 4 },
        LearnerKind::Constructive {
            total: 4,
            steps_per_stage: 40_000,
        },
        LearnerKind::Ccn {
            total: 6,
            per_stage: 3,
            steps_per_stage: 60_000,
        },
        LearnerKind::Tbptt { d: 3, k: 25 },
        LearnerKind::Snap1 { d: 4 },
    ];
    for learner in learners {
        let label = learner.label();
        let mut c = cfg(EnvKind::CycleWorld { n: 8 }, learner, 0.01, 120_000, 0);
        c.lambda = 0.9;
        let res = run_experiment(&c).unwrap();
        let imp = improvement(&res);
        assert!(
            imp > 10.0,
            "{label}: error must drop >10x on cycle_world_8 \
             (early/tail = {imp:.2}, tail = {:.5})",
            res.tail_error
        );
    }
}

#[test]
fn tbptt_learns_trace_conditioning() {
    // the delayed-US memory task: T-BPTT with k=25 > ISI learns it
    // (calibrated 1.6x improvement at 200k steps).
    let mut c = cfg(
        EnvKind::TraceConditioning,
        LearnerKind::Tbptt { d: 3, k: 25 },
        0.003,
        200_000,
        0,
    );
    c.lambda = 0.99;
    let res = run_experiment(&c).unwrap();
    let imp = improvement(&res);
    assert!(
        imp > 1.3,
        "tbptt on trace conditioning: early/tail = {imp:.2}"
    );
}

#[test]
fn ccn_learns_trace_conditioning() {
    // CCN-family learning on the memory task is slower than T-BPTT at
    // small step counts (the paper's Fig-4 curves need millions of
    // steps); calibrated: 1.23x improvement at 600k steps. The full
    // trace-patterning comparison runs in benches/fig4 at proper scale.
    let mut c = cfg(
        EnvKind::TraceConditioning,
        LearnerKind::Ccn {
            total: 6,
            per_stage: 3,
            steps_per_stage: 220_000,
        },
        0.003,
        600_000,
        0,
    );
    c.lambda = 0.99;
    let res = run_experiment(&c).unwrap();
    let imp = improvement(&res);
    assert!(
        imp > 1.1,
        "ccn on trace conditioning: early/tail = {imp:.2}, tail = {:.5}",
        res.tail_error
    );
}

#[test]
fn sweep_aggregates_multiple_seeds() {
    let base = cfg(
        EnvKind::CycleWorld { n: 6 },
        LearnerKind::Columnar { d: 3 },
        0.01,
        40_000,
        0,
    );
    let configs = sweep::seeds(&base, &[0, 1, 2]);
    let res = run_sweep(configs, 3).unwrap();
    let aggs = aggregate_runs(&res.runs);
    assert_eq!(aggs.len(), 1);
    assert_eq!(aggs[0].n_seeds, 3);
    assert!(aggs[0].tail_mean.is_finite());
    assert!(aggs[0].curve_mean.len() > 5);
}

#[test]
fn atari_stream_learners_stay_stable() {
    // 277-input synthetic-ALE stream: no NaN, error finite, some learning.
    for learner in [
        LearnerKind::Columnar { d: 4 },
        LearnerKind::Tbptt { d: 2, k: 8 },
    ] {
        let label = learner.label();
        let res = run_experiment(&cfg(
            EnvKind::SynthAtari {
                game: "blinkgrid".into(),
            },
            learner,
            0.001,
            60_000,
            0,
        ))
        .unwrap();
        assert!(
            res.tail_error.is_finite() && res.tail_error >= 0.0,
            "{label}: tail {:?}",
            res.tail_error
        );
        assert!(res.curve.ys.iter().all(|v| v.is_finite()), "{label}");
    }
}
