//! End-to-end tests of the telemetry layer: the `metrics` wire op must
//! report per-op latency histograms for every protocol op plus the
//! internal stage timers, over both stdio and TCP; the transport error
//! taxonomy must categorize failures per connection and server-wide;
//! and tracing at sample rate 1 must leave predictions and replies
//! bit-exact while producing a parseable JSONL trace.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use ccn_rtrl::obs::{MetricsServer, TraceConfig};
use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::store::StoreConfig;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

/// The nine session-facing protocol ops every metrics reply must cover.
const NINE_OPS: [&str; 9] = [
    "open",
    "step",
    "step_batch",
    "predict",
    "snapshot",
    "restore",
    "park",
    "warm",
    "close",
];

fn tempdir(tag: &str) -> std::path::PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    std::env::temp_dir().join(format!(
        "ccn-obs-{tag}-{}-{nanos}",
        std::process::id()
    ))
}

fn ok(reply: &str) -> Json {
    let v = Json::parse(reply).expect("reply must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(true)),
        "expected ok reply, got: {reply}"
    );
    v
}

fn num(v: &Json, key: &str) -> f64 {
    v.get(key)
        .unwrap_or_else(|| panic!("missing key {key:?} in {v:?}"))
        .as_f64()
        .unwrap_or_else(|| panic!("key {key:?} is not a number in {v:?}"))
}

fn step_line(id: u64, x: &[f32], c: f32) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(r#"{{"op":"step","id":{id},"x":[{}],"c":{c}}}"#, xs.join(","))
}

/// Drive all nine session ops against `service` (which must have a
/// store mounted, so park/warm hit real store I/O). Returns the number
/// of request lines issued.
fn drive_nine_ops(service: &Service) -> usize {
    let mut lines = 0usize;
    let mut run = |line: &str| -> Json {
        lines += 1;
        ok(&service.handle_line(line))
    };
    let id1 = run(r#"{"op":"open","learner":"columnar:4","n_inputs":3,"seed":1}"#)
        .get("id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;
    let id2 = run(r#"{"op":"open","learner":"ccn:4:2:1000","n_inputs":3,"seed":2}"#)
        .get("id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;
    let mut rng = Xoshiro256::seed_from_u64(7);
    for _ in 0..20 {
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        run(&step_line(id1, &x, 0.1));
        run(&step_line(id2, &x, -0.1));
    }
    run(&format!(
        r#"{{"op":"step_batch","ids":[{id1},{id2}],"xs":[[0.1,0.2,0.3],[0.1,0.2,0.3]],"cs":[0.0,0.0]}}"#
    ));
    run(&format!(r#"{{"op":"predict","id":{id1},"x":[0.5,0.5,0.5]}}"#));
    let state = run(&format!(r#"{{"op":"snapshot","id":{id1}}}"#))
        .get("state")
        .unwrap()
        .clone();
    let restore =
        Json::obj(vec![("op", Json::Str("restore".into())), ("state", state)]);
    let id3 = run(&restore.dump()).get("id").unwrap().as_f64().unwrap() as u64;
    run(&format!(r#"{{"op":"park","id":{id2}}}"#));
    let warmed = run(&format!(r#"{{"op":"warm","id":{id2}}}"#));
    assert_eq!(
        warmed.get("rehydrated"),
        Some(&Json::Bool(true)),
        "parked session must rehydrate from the store: {warmed:?}"
    );
    run(&format!(r#"{{"op":"close","id":{id3}}}"#));
    lines
}

/// One embedded histogram object: schema keys present, count positive,
/// and the percentile ladder monotone between the observed extrema.
fn assert_histogram_sane(name: &str, h: &Json) {
    let count = num(h, "count");
    assert!(count >= 1.0, "{name}: expected count >= 1, got {count}");
    let ladder = [
        num(h, "min_ns"),
        num(h, "p50_ns"),
        num(h, "p90_ns"),
        num(h, "p99_ns"),
        num(h, "p999_ns"),
        num(h, "max_ns"),
    ];
    for w in ladder.windows(2) {
        assert!(
            w[0] <= w[1],
            "{name}: percentile ladder not monotone: {ladder:?}"
        );
    }
    let bucket_total: f64 = h
        .get("buckets")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|pair| pair.as_arr().unwrap()[1].as_f64().unwrap())
        .sum();
    assert_eq!(
        bucket_total, count,
        "{name}: bucket counts must sum to count"
    );
}

fn assert_metrics_reply(reply: &Json) {
    let ops = reply.get("ops").expect("metrics reply carries ops").as_obj().unwrap();
    for op in NINE_OPS {
        let h = ops
            .get(op)
            .unwrap_or_else(|| panic!("metrics must cover op {op:?}"));
        assert_histogram_sane(&format!("op.{op}"), h);
    }
    let stages = reply
        .get("stages")
        .expect("metrics reply carries stages")
        .as_obj()
        .unwrap();
    // every routed op waited in a shard queue; steps ran a kernel; the
    // park/warm pair hit real store I/O
    for stage in ["queue_wait", "store_append", "store_load"] {
        let h = stages
            .get(stage)
            .unwrap_or_else(|| panic!("metrics must cover stage {stage:?}"));
        assert_histogram_sane(&format!("stage.{stage}"), h);
    }
    let kernel_steps = num(stages.get("step_scalar").unwrap(), "count")
        + num(stages.get("step_batched").unwrap(), "count");
    assert!(
        kernel_steps >= 1.0,
        "stepping must land in a kernel stage timer"
    );
    assert!(
        reply.get("counters").is_some(),
        "metrics reply carries the counter block"
    );
}

#[test]
fn metrics_reports_all_nine_ops_and_stage_timers_over_stdio() {
    let dir = tempdir("stdio");
    let mut service =
        Service::with_store(2, Some(StoreConfig::new(&dir, 0))).expect("boot");
    drive_nine_ops(&service);

    let metrics = ok(&service.handle_line(r#"{"op":"metrics"}"#));
    assert_metrics_reply(&metrics);

    // stats gains the compact per-op latency block
    let stats = ok(&service.handle_line(r#"{"op":"stats"}"#));
    let latency = stats
        .get("latency")
        .expect("stats reply carries latency")
        .as_obj()
        .unwrap();
    for op in NINE_OPS {
        let entry = latency
            .get(op)
            .unwrap_or_else(|| panic!("stats latency must cover op {op:?}"));
        assert!(num(entry, "count") >= 1.0, "{op}: latency count");
        assert!(num(entry, "p50_us") <= num(entry, "p99_us"), "{op}: p50 <= p99");
    }

    service.close().expect("close");
    let _ = std::fs::remove_dir_all(&dir);
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(local: &str) -> Client {
        let hostport = local.strip_prefix("tcp://").expect("tcp local addr");
        let stream = TcpStream::connect(hostport).expect("connect");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: stream,
        }
    }

    fn call(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").expect("send");
        self.writer.flush().expect("flush");
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("recv");
        Json::parse(reply.trim()).expect("reply must be valid json")
    }
}

#[test]
fn metrics_and_error_taxonomy_over_tcp() {
    let dir = tempdir("tcp");
    let service =
        Service::with_store(2, Some(StoreConfig::new(&dir, 0))).expect("boot");
    drive_nine_ops(&service);
    let server = Server::bind(
        service,
        &ListenAddr::parse("tcp://127.0.0.1:0").expect("addr"),
        0,
    )
    .expect("bind");
    let mut client = Client::connect(&server.local_addr().to_string());

    // a healthy request, then one failure per taxonomy category that
    // still produces a reply
    let opened =
        client.call(r#"{"op":"open","learner":"columnar:4","n_inputs":3,"seed":9}"#);
    assert_eq!(opened.get("ok"), Some(&Json::Bool(true)));
    let garbage = client.call("this is not json");
    assert_eq!(garbage.get("ok"), Some(&Json::Bool(false)), "{garbage:?}");
    let ghost = client.call(r#"{"op":"step","id":999999,"x":[0,0,0],"c":0}"#);
    assert_eq!(ghost.get("ok"), Some(&Json::Bool(false)), "{ghost:?}");

    // the metrics op is served over the wire, with the nine-op coverage
    // from the pre-bind stdio traffic plus live transport stage timers
    let metrics = client.call(r#"{"op":"metrics"}"#);
    assert_eq!(metrics.get("ok"), Some(&Json::Bool(true)));
    assert_metrics_reply(&metrics);
    let stages = metrics.get("stages").unwrap().as_obj().unwrap();
    for stage in ["transport_read", "transport_decode", "transport_write"] {
        assert_histogram_sane(
            &format!("stage.{stage}"),
            stages.get(stage).unwrap(),
        );
    }
    let counters = metrics.get("counters").unwrap().as_obj().unwrap();
    assert!(
        counters.get("transport.err_decode").unwrap().as_f64().unwrap() >= 1.0,
        "garbage line must count as a decode error"
    );
    assert!(
        counters.get("transport.err_ghost_id").unwrap().as_f64().unwrap() >= 1.0,
        "unknown session id must count as a ghost-id error"
    );

    // per-connection taxonomy in the stats transport block
    let stats = client.call(r#"{"op":"stats"}"#);
    let transport = stats.get("transport").expect("transport block").clone();
    let conns = transport.get("conns").unwrap().as_arr().unwrap();
    let me = conns
        .iter()
        .find(|c| num(c, "id") == num(&transport, "conn"))
        .expect("asking connection is listed");
    assert!(num(me, "err_decode") >= 1.0, "{me:?}");
    assert!(num(me, "err_ghost_id") >= 1.0, "{me:?}");
    assert_eq!(num(me, "err_oversize"), 0.0, "{me:?}");
    // the taxonomy splits the pre-existing total without changing it:
    // both failures above are also counted under errors
    assert!(num(me, "errors") >= 2.0, "{me:?}");

    server.shutdown().expect("shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tracing_at_sample_one_is_bit_exact_and_trace_parses() {
    let dir_traced = tempdir("twin-traced");
    let dir_plain = tempdir("twin-plain");
    let trace_path = tempdir("trace-log").with_extension("jsonl");

    // resident cap 1 forces store churn mid-sequence, so the traced
    // path also covers evict/rehydrate I/O
    let mut traced =
        Service::with_store(2, Some(StoreConfig::new(&dir_traced, 1))).expect("boot");
    traced
        .set_trace(&TraceConfig { path: trace_path.clone(), sample: 1 })
        .expect("mount trace");
    let mut plain =
        Service::with_store(2, Some(StoreConfig::new(&dir_plain, 1))).expect("boot");

    // telemetry is measurement-only: with tracing sampling every op,
    // every reply must be byte-identical to the untraced twin's. Both
    // twins boot from fresh stores, so they mint identical session ids.
    let mut n_ops = 0usize;
    let mut run_twin = |line: &str| -> String {
        n_ops += 1;
        let a = traced.handle_line(line);
        let b = plain.handle_line(line);
        assert_eq!(a, b, "traced reply diverged for request {line}");
        a
    };
    let ids: Vec<u64> = [
        r#"{"op":"open","learner":"columnar:4","n_inputs":3,"seed":1}"#,
        r#"{"op":"open","learner":"tbptt:3:8","n_inputs":3,"seed":2}"#,
        r#"{"op":"open","learner":"snap1:3","n_inputs":3,"seed":3}"#,
    ]
    .iter()
    .map(|line| ok(&run_twin(line)).get("id").unwrap().as_f64().unwrap() as u64)
    .collect();
    let mut rng = Xoshiro256::seed_from_u64(0x0b5);
    for round in 0..15 {
        for &id in &ids {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            run_twin(&step_line(id, &x, 0.2));
            if round % 5 == 4 {
                run_twin(&format!(
                    r#"{{"op":"predict","id":{id},"x":[0.1,0.2,0.3]}}"#
                ));
            }
        }
    }
    run_twin(&format!(r#"{{"op":"snapshot","id":{}}}"#, ids[0]));
    run_twin(&format!(r#"{{"op":"park","id":{}}}"#, ids[1]));
    run_twin(&format!(r#"{{"op":"warm","id":{}}}"#, ids[1]));
    run_twin(&format!(r#"{{"op":"close","id":{}}}"#, ids[2]));
    drop(run_twin);

    traced.close().expect("close traced");
    plain.close().expect("close plain");

    // every sampled op produced one parseable event (the queue is far
    // larger than this sequence, so nothing may drop)
    let log = std::fs::read_to_string(&trace_path).expect("trace file");
    let mut events = 0usize;
    for line in log.lines() {
        let v = Json::parse(line).expect("trace event must be valid json");
        for key in ["ts_ns", "op", "dur_ns"] {
            assert!(v.get(key).is_some(), "trace event missing {key:?}: {line}");
        }
        assert!(num(&v, "ts_ns") >= 0.0);
        assert!(num(&v, "dur_ns") >= 0.0);
        assert!(v.get("ok").unwrap().as_bool().is_some(), "{line}");
        events += 1;
    }
    assert_eq!(events, n_ops, "sample rate 1 records every op exactly once");

    let _ = std::fs::remove_dir_all(&dir_traced);
    let _ = std::fs::remove_dir_all(&dir_plain);
    let _ = std::fs::remove_file(&trace_path);
}

/// One raw HTTP/1.1 GET against the exposition endpoint; returns the
/// full response (status line, headers, body). The server closes the
/// connection after each response, so read-to-end terminates.
fn http_get(hostport: &str, path: &str) -> String {
    use std::io::Read;
    let mut stream = TcpStream::connect(hostport).expect("connect scrape");
    write!(stream, "GET {path} HTTP/1.1\r\nHost: ccn\r\n\r\n").expect("send");
    stream.flush().expect("flush");
    let mut out = String::new();
    stream.read_to_string(&mut out).expect("recv scrape");
    out
}

#[test]
fn exposition_endpoint_is_scrapeable_and_measurement_only() {
    let dir_scraped = tempdir("expo-scraped");
    let dir_plain = tempdir("expo-plain");
    let scraped = Service::with_store(2, Some(StoreConfig::new(&dir_scraped, 0)))
        .expect("boot");
    let mut plain = Service::with_store(2, Some(StoreConfig::new(&dir_plain, 0)))
        .expect("boot");
    let metrics = MetricsServer::bind(
        &ListenAddr::parse("tcp://127.0.0.1:0").expect("addr"),
        std::sync::Arc::clone(scraped.registry()),
    )
    .expect("bind metrics");
    let hostport = metrics
        .local_addr()
        .strip_prefix("tcp://")
        .expect("tcp exposition addr")
        .to_string();

    // hammer the endpoint from a background thread while the twin
    // drive runs: scraping must never perturb protocol replies
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let scraper = {
        let stop = std::sync::Arc::clone(&stop);
        let hostport = hostport.clone();
        std::thread::spawn(move || {
            let mut scrapes = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let resp = http_get(&hostport, "/metrics");
                assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
                scrapes += 1;
            }
            scrapes
        })
    };

    let mut scraped_service = scraped; // drive_nine_ops takes &Service
    drive_nine_ops(&scraped_service);
    drive_nine_ops(&plain);
    let mut rng = Xoshiro256::seed_from_u64(0xE1);
    for _ in 0..30 {
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let line = format!(
            r#"{{"op":"open","learner":"columnar:4","n_inputs":3,"seed":{}}}"#,
            (x[0].abs() * 100.0) as u64
        );
        let a = scraped_service.handle_line(&line);
        let b = plain.handle_line(&line);
        assert_eq!(a, b, "scraped reply diverged for request {line}");
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let scrapes = scraper.join().expect("scraper thread");
    assert!(scrapes >= 1, "the scraper must have gotten at least one 200");

    // final scrape: every protocol op histogram is exported, buckets are
    // cumulative and monotone, and _count equals the +Inf bucket
    let resp = http_get(&hostport, "/metrics");
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(
        resp.contains("text/plain; version=0.0.4"),
        "prometheus text content type: {resp}"
    );
    let body = resp.split("\r\n\r\n").nth(1).expect("body");
    for op in NINE_OPS.iter().chain(["stats", "metrics", "ping"].iter()) {
        assert!(
            body.contains(&format!("ccn_op_{op}_ns_count ")),
            "exposition must carry series for op {op}"
        );
    }
    let mut cum = Vec::new();
    let mut inf = None;
    let mut count = None;
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("ccn_op_step_ns_bucket{le=\"") {
            let (le, n) = rest.split_once("\"} ").expect("bucket line shape");
            let n: f64 = n.parse().expect("bucket count");
            cum.push(n);
            if le == "+Inf" {
                inf = Some(n);
            }
        } else if let Some(n) = line.strip_prefix("ccn_op_step_ns_count ") {
            count = Some(n.parse::<f64>().expect("count value"));
        }
    }
    assert!(cum.len() >= 2, "step histogram has buckets: {body}");
    for w in cum.windows(2) {
        assert!(w[0] <= w[1], "cumulative buckets must be monotone: {cum:?}");
    }
    assert_eq!(inf, count, "+Inf bucket must equal _count");
    assert!(count.unwrap() >= 40.0, "40 twin steps were driven");
    // windowed gauges ride along
    assert!(
        body.contains("ccn_window_steps{window=\"60s\"}"),
        "windowed gauges are exported: {body}"
    );

    // anything but GET /metrics is a clean 404
    let resp = http_get(&hostport, "/other");
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");

    metrics.shutdown();
    scraped_service.close().expect("close scraped");
    plain.close().expect("close plain");
    let _ = std::fs::remove_dir_all(&dir_scraped);
    let _ = std::fs::remove_dir_all(&dir_plain);
}
