//! Cross-language / cross-path parity: the native Rust column
//! implementation, the JAX/Pallas-lowered artifacts executed via PJRT,
//! and the build-time golden fixture must all agree numerically.
//!
//! This is the reproduction of the paper's correctness methodology
//! ("gradients given by our implementation and those by PyTorch match
//! exactly"), upgraded to three independent implementations.
//!
//! Requires `make artifacts` to have run; tests skip (with a note) when
//! the artifact directory is absent so `cargo test` works standalone.
//! The whole file is gated on the `pjrt` feature (the `xla` crate is not
//! available in the offline toolchain).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use ccn_rtrl::nets::lstm_column::LstmColumn;
use ccn_rtrl::nets::normalizer::{OnlineNormalizer, NORM_BETA};
use ccn_rtrl::runtime::{PjrtColumnarStage, PjrtRuntime};
use ccn_rtrl::util::prng::Xoshiro256;

fn artifacts_dir() -> Option<PathBuf> {
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

#[test]
fn golden_fixture_matches_pjrt_execution() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = PjrtRuntime::load(&dir).expect("pjrt runtime");
    rt.verify_golden().expect("golden check");
}

#[test]
fn native_and_pjrt_stay_in_lockstep() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = PjrtRuntime::load(&dir).expect("pjrt runtime");
    let (n_cols, m) = (3, 4); // the golden/test shape
    let mut stage = PjrtColumnarStage::new(&rt, n_cols, m, 7).expect("stage");

    // native twins with identical parameters
    let mut rng = Xoshiro256::seed_from_u64(123);
    let mut cols: Vec<LstmColumn> = (0..n_cols)
        .map(|_| LstmColumn::new(m, &mut rng, 1.0))
        .collect();
    stage.set_params_from_columns(&cols);
    // native normalizer mirroring the artifact's baked eps
    let eps = rt.manifest.eps;
    let mut norm = OnlineNormalizer::new(n_cols, NORM_BETA, eps);
    let mut h_norm_native = vec![0.0f32; n_cols];

    for step in 0..50 {
        let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        stage.step(&x).expect("pjrt step");
        let mut raw = vec![0.0f32; n_cols];
        for (k, col) in cols.iter_mut().enumerate() {
            col.step_with_traces(&x);
            raw[k] = col.h;
        }
        norm.update_and_normalize(&raw, &mut h_norm_native);

        for k in 0..n_cols {
            let a = stage.h[k];
            let b = cols[k].h;
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                "step {step} col {k}: h pjrt {a} vs native {b}"
            );
            let an = stage.h_norm[k];
            let bn = h_norm_native[k];
            assert!(
                (an - bn).abs() < 1e-3 * (1.0 + bn.abs()),
                "step {step} col {k}: h_norm pjrt {an} vs native {bn}"
            );
        }
        // traces too — the actual learning signal
        for k in 0..n_cols {
            for j in 0..4 * m {
                let a = stage.thw[k * 4 * m + j];
                let b = cols[k].thw[j];
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "step {step} col {k} thw[{j}]: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn frozen_pjrt_path_matches_native_forward() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = PjrtRuntime::load(&dir).expect("pjrt runtime");
    let (n_cols, m) = (3, 4);
    let mut stage = PjrtColumnarStage::new(&rt, n_cols, m, 11).expect("stage");
    let mut rng = Xoshiro256::seed_from_u64(321);
    let mut cols: Vec<LstmColumn> = (0..n_cols)
        .map(|_| LstmColumn::new(m, &mut rng, 1.0))
        .collect();
    stage.set_params_from_columns(&cols);
    for step in 0..30 {
        let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        stage.step_frozen(&x).expect("pjrt fwd");
        for (k, col) in cols.iter_mut().enumerate() {
            col.step_forward_only(&x);
            assert!(
                (stage.h[k] - col.h).abs() < 1e-4,
                "step {step} col {k}: {} vs {}",
                stage.h[k],
                col.h
            );
        }
    }
}

#[test]
fn pjrt_gradient_contract_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = PjrtRuntime::load(&dir).expect("pjrt runtime");
    let (n_cols, m) = (3, 4);
    let mut stage = PjrtColumnarStage::new(&rt, n_cols, m, 5).expect("stage");
    let mut rng = Xoshiro256::seed_from_u64(55);
    let mut cols: Vec<LstmColumn> = (0..n_cols)
        .map(|_| LstmColumn::new(m, &mut rng, 1.0))
        .collect();
    stage.set_params_from_columns(&cols);
    let eps = rt.manifest.eps;
    let mut norm = OnlineNormalizer::new(n_cols, NORM_BETA, eps);
    let mut scratch = vec![0.0f32; n_cols];
    for _ in 0..20 {
        let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        stage.step(&x).unwrap();
        let mut raw = vec![0.0f32; n_cols];
        for (k, col) in cols.iter_mut().enumerate() {
            col.step_with_traces(&x);
            raw[k] = col.h;
        }
        norm.update_and_normalize(&raw, &mut scratch);
    }
    let per = 4 * m + 8;
    let w_k = 0.7f32;
    for k in 0..n_cols {
        let mut g_pjrt = vec![0.0f32; per];
        stage.write_grad(k, w_k, &mut g_pjrt);
        let mut g_native = vec![0.0f32; per];
        cols[k].write_grad(w_k / norm.denom(k), &mut g_native);
        for (a, b) in g_pjrt.iter().zip(&g_native) {
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                "col {k}: grad {a} vs {b}"
            );
        }
    }
}
