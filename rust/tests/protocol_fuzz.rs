//! Protocol torture/property suite: seeded-random malformed JSONL fed
//! straight into the serve loop.
//!
//! The contract under test: **every** input line — truncated requests,
//! surrogate-abusing strings, nesting bombs, wrong-typed fields,
//! megabyte lines, valid ops aimed at nonsense ids — yields exactly one
//! parseable JSON response carrying an `"ok"` boolean. Never a panic,
//! never a wedged shard: sessions opened *before* the garbage keep
//! stepping bit-exactly *after* it (verified against twin sessions on a
//! service that never saw the storm).

use ccn_rtrl::serve::Service;
use ccn_rtrl::util::check::{check, Gen};
use ccn_rtrl::util::json::Json;

const KINDS: [&str; 5] = [
    "columnar:4",
    "constructive:4:60",
    "ccn:6:2:60",
    "tbptt:3:8",
    "snap1:3",
];

fn ok(reply: &str) -> Json {
    let v = Json::parse(reply).expect("response must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(true)),
        "expected ok response, got: {reply}"
    );
    v
}

fn open_id(service: &Service, spec: &str, seed: u64) -> u64 {
    let line = format!(
        r#"{{"op":"open","learner":"{spec}","n_inputs":3,"seed":{seed}}}"#
    );
    ok(&service.handle_line(&line)).get("id").unwrap().as_f64().unwrap() as u64
}

fn step_line(id: u64, x: &[f32], c: f32) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(r#"{{"op":"step","id":{id},"x":[{}],"c":{c}}}"#, xs.join(","))
}

fn step_y(service: &Service, line: &str) -> f64 {
    ok(&service.handle_line(line)).get("y").unwrap().as_f64().unwrap()
}

/// The reply contract: one line, valid JSON, with a boolean `"ok"`.
fn assert_contract(line: &str, reply: &str) -> Result<(), String> {
    if reply.contains('\n') {
        return Err(format!("multi-line reply to {line:?}: {reply:?}"));
    }
    let v = Json::parse(reply)
        .map_err(|e| format!("unparseable reply to {line:?}: {e}"))?;
    match v.get("ok") {
        Some(Json::Bool(_)) => Ok(()),
        other => Err(format!(
            "reply to {line:?} has no boolean 'ok' (got {other:?}): {reply}"
        )),
    }
}

/// One seeded malformed (or adversarially shaped) request line.
fn garbage_line(g: &mut Gen, live_ids: &[u64]) -> String {
    let id = live_ids[g.usize_in(0, live_ids.len() - 1)];
    match g.usize_in(0, 11) {
        // raw character soup (always valid utf-8: handle_line takes &str)
        0 => {
            const POOL: &[char] = &[
                '{', '}', '[', ']', '"', ':', ',', '\\', 'a', '0', '-',
                '.', ' ', '\t', 'π', '😀', '\u{0000}', '\u{FFFD}', 'e',
                'n', 'u', 'l', 't', 'r',
            ];
            let len = g.sized_usize(1, 200);
            (0..len).map(|_| POOL[g.usize_in(0, POOL.len() - 1)]).collect()
        }
        // a valid request truncated at a random char boundary
        1 => {
            let full = if g.bool() {
                step_line(id, &[0.1, -0.2, 0.3], 0.5)
            } else {
                format!(
                    r#"{{"op":"open","learner":"{}","n_inputs":3,"seed":1}}"#,
                    KINDS[g.usize_in(0, KINDS.len() - 1)]
                )
            };
            let cut = g.usize_in(0, full.chars().count().saturating_sub(1));
            full.chars().take(cut).collect()
        }
        // surrogate-abusing \u escapes (lone halves, reversed pairs)
        2 => {
            const BAD: [&str; 5] = [
                r#"{"op":"open","learner":"\ud800","n_inputs":3}"#,
                r#"{"op":"\udc00step","id":1}"#,
                r#"{"op":"step","id":1,"x":[1,2,3],"c":0,"tag":"\ud800x"}"#,
                r#"{"op":"\ude00\ud83d"}"#,
                r#"{"\ud800":1,"op":"stats"}"#,
            ];
            BAD[g.usize_in(0, BAD.len() - 1)].to_string()
        }
        // a *valid* surrogate pair: parses, then fails as unknown op
        3 => r#"{"op":"😀"}"#.to_string(),
        // nesting bombs, bare and tucked inside a field of a valid op
        // (depths straddle the parser's MAX_DEPTH of 128)
        4 => {
            let depth = g.usize_in(4, 4_000);
            if g.bool() {
                "[".repeat(depth)
            } else {
                format!(
                    r#"{{"op":"step","id":{id},"x":{}{}{},"c":0}}"#,
                    "[".repeat(depth),
                    "0.5",
                    "]".repeat(depth)
                )
            }
        }
        // wrong-typed fields on every op
        5 => {
            let templates = [
                r#"{"op":"step","id":"one","x":[1,2,3],"c":0}"#.to_string(),
                r#"{"op":"step","id":-4,"x":[1,2,3],"c":0}"#.to_string(),
                format!(r#"{{"op":"step","id":{id},"x":"wide","c":0}}"#),
                format!(r#"{{"op":"step","id":{id},"x":[1,"a",3],"c":0}}"#),
                format!(r#"{{"op":"step","id":{id},"x":[1,2,3],"c":[]}}"#),
                r#"{"op":"open","learner":42,"n_inputs":3}"#.to_string(),
                r#"{"op":"open","learner":"columnar:4","n_inputs":"3"}"#
                    .to_string(),
                r#"{"op":"open","learner":"columnar:4","n_inputs":3,"alpha":{"v":1}}"#
                    .to_string(),
                r#"{"op":"restore","state":"not-an-envelope"}"#.to_string(),
                r#"{"op":"restore","state":{"v":99,"kind":"columnar"}}"#
                    .to_string(),
                r#"{"op":"step_batch","ids":[1,2],"xs":[[1]],"cs":[0,0]}"#
                    .to_string(),
                r#"{"op":"step_batch","ids":"all","xs":[],"cs":[]}"#.to_string(),
                format!(r#"{{"op":"snapshot","id":{}}}"#, u64::MAX),
                r#"{"op":null}"#.to_string(),
                r#"[{"op":"stats"}]"#.to_string(),
                r#""stats""#.to_string(),
                r#"12345"#.to_string(),
            ];
            templates[g.usize_in(0, templates.len() - 1)].clone()
        }
        // big lines: tens-of-KB to ~0.5MB of x payload or string junk
        // (the flat-1MB case has its own dedicated test)
        6 => {
            let n = g.usize_in(10, 60_000);
            if g.bool() {
                // a huge (wrong-width) observation on a real session
                let xs = vec!["0.125"; n].join(",");
                format!(r#"{{"op":"step","id":{id},"x":[{xs}],"c":0}}"#)
            } else {
                format!(r#"{{"op":"open","learner":"{}"}}"#, "g".repeat(n * 8))
            }
        }
        // valid ops aimed at ids that do not exist
        7 => {
            let ghost = 10_000 + g.usize_in(0, 1000) as u64;
            let ops = [
                step_line(ghost, &[0.1, 0.2, 0.3], 0.0),
                format!(r#"{{"op":"snapshot","id":{ghost}}}"#),
                format!(r#"{{"op":"close","id":{ghost}}}"#),
                format!(r#"{{"op":"park","id":{ghost}}}"#),
                format!(r#"{{"op":"warm","id":{ghost}}}"#),
                format!(r#"{{"op":"predict","id":{ghost},"x":[1,2,3]}}"#),
            ];
            ops[g.usize_in(0, ops.len() - 1)].clone()
        }
        // structurally valid JSON that is not a request object
        8 => {
            const SHAPES: [&str; 5] =
                ["null", "true", "[]", "{}", r#"{"ok":true}"#];
            SHAPES[g.usize_in(0, SHAPES.len() - 1)].to_string()
        }
        // duplicate keys / trailing junk / unterminated strings
        9 => {
            const SHAPES: [&str; 4] = [
                r#"{"op":"stats","op":"step"}"#,
                r#"{"op":"stats"} {"op":"stats"}"#,
                r#"{"op":"stats"#,
                r#"{"op":"stats"}]"#,
            ];
            SHAPES[g.usize_in(0, SHAPES.len() - 1)].to_string()
        }
        // bad escapes and bad numbers
        10 => {
            const SHAPES: [&str; 5] = [
                r#"{"op":"step","id":1e999,"x":[1,2,3],"c":0}"#,
                r#"{"op":"step","id":1,"x":[1,2,3],"c":-}"#,
                r#"{"op":"step","id":1,"x":[01],"c":0}"#,
                r#"{"op":"st\qep"}"#,
                r#"{"op":"step","id":1,"x":[1,2,3],"c":0,}"#,
            ];
            SHAPES[g.usize_in(0, SHAPES.len() - 1)].to_string()
        }
        // a wrong-width but otherwise perfect step on a live session
        _ => step_line(id, &[0.5; 7], 0.1),
    }
}

#[test]
fn torture_lines_never_wedge_the_service_or_corrupt_sessions() {
    let service = Service::new(2);
    let twin = Service::new(2);
    let mut ids = Vec::new();
    for (s, spec) in KINDS.iter().enumerate() {
        let a = open_id(&service, spec, s as u64);
        let b = open_id(&twin, spec, s as u64);
        assert_eq!(a, b, "twin services must allocate identical ids");
        ids.push(a);
    }
    // settle both populations identically before the storm
    for t in 0..30 {
        for &id in &ids {
            let line = step_line(id, &[0.1, -0.05 * t as f32, 0.3], 0.2);
            assert_eq!(step_y(&service, &line), step_y(&twin, &line));
        }
    }
    // the storm: garbage interleaved with valid traffic; every reply
    // honors the contract and valid traffic stays bit-exact throughout
    check("protocol torture", 120, |g| {
        for _ in 0..g.usize_in(1, 4) {
            let line = garbage_line(g, &ids);
            let reply = service.handle_line(&line);
            assert_contract(&line, &reply)?;
        }
        let id = ids[g.usize_in(0, ids.len() - 1)];
        let x = g.f32_vec(3, -1.0, 1.0);
        let c = g.f32_in(-0.5, 0.5);
        let line = step_line(id, &x, c);
        let ya = step_y(&service, &line);
        let yb = step_y(&twin, &line);
        if ya != yb {
            return Err(format!(
                "session {id} diverged from its twin after garbage: {ya} vs {yb}"
            ));
        }
        Ok(())
    });
    // after the storm: every session still steps bit-exactly, and the
    // service still answers aggregates
    for t in 0..50 {
        for &id in &ids {
            let line = step_line(id, &[0.01 * t as f32, 0.2, -0.3], -0.1);
            assert_eq!(
                step_y(&service, &line),
                step_y(&twin, &line),
                "session {id} corrupted by the torture run"
            );
        }
    }
    let stats = ok(&service.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(
        stats.get("sessions"),
        Some(&Json::Num(KINDS.len() as f64)),
        "sessions lost during the torture run"
    );
}

/// A flat 1MB line — one valid-shaped op with a massive payload and one
/// of pure noise — must produce a single error reply, not a hang or OOM
/// spiral, and the service must keep serving.
#[test]
fn megabyte_lines_get_one_error_reply_each() {
    let service = Service::new(1);
    let id = open_id(&service, "columnar:4", 0);
    let xs = vec!["0.25"; 131_072].join(","); // ~0.8MB of numbers
    let wide = format!(r#"{{"op":"step","id":{id},"x":[{xs}],"c":0}}"#);
    assert!(wide.len() > 700_000);
    let reply = service.handle_line(&wide);
    assert_contract(&wide, &reply).unwrap();
    assert!(reply.contains("\"ok\":false"), "oversized x must error: {reply}");

    let noise = "x".repeat(1 << 20);
    let reply = service.handle_line(&noise);
    assert_contract(&noise, &reply).unwrap();
    assert!(reply.contains("\"ok\":false"));

    // still alive and numerically sane
    let y = step_y(&service, &step_line(id, &[0.1, 0.2, 0.3], 0.5));
    assert!(y.is_finite());
}

/// The parser rejects lone surrogates and nesting bombs with errors (not
/// aborts), and the serve loop wraps those errors in the reply contract.
#[test]
fn surrogates_and_nesting_bombs_are_structured_errors() {
    let service = Service::new(1);
    for line in [
        r#"{"op":"open","learner":"\ud800bad","n_inputs":3}"#.to_string(),
        r#"{"op":"\udc00"}"#.to_string(),
        "[".repeat(500_000),
        format!(r#"{{"x":{}1{}}}"#, "[".repeat(3_000), "]".repeat(3_000)),
    ] {
        let reply = service.handle_line(&line);
        assert_contract(&line, &reply).unwrap();
        assert!(
            reply.contains("\"ok\":false"),
            "line {:.40}... must error, got {reply}",
            line
        );
    }
    // a *paired* surrogate is legal JSON — it fails later, as an op error
    let reply = service.handle_line(r#"{"op":"😀"}"#);
    let v = Json::parse(&reply).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    assert!(
        v.get("error").and_then(|e| e.as_str()).unwrap().contains("unknown op"),
        "{reply}"
    );
}
