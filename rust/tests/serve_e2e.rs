//! End-to-end tests of the serve subsystem over the JSONL wire protocol:
//! the acceptance path is open -> step x N -> snapshot -> restore ->
//! close, with the restored session continuing identically to the
//! original — for every registered net kind, plus the v1 -> v2 snapshot
//! migration shim.

use ccn_rtrl::serve::Service;
use ccn_rtrl::util::check::check;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

fn ok(reply: &str) -> Json {
    let v = Json::parse(reply).expect("response must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(true)),
        "expected ok response, got: {reply}"
    );
    v
}

fn err(reply: &str) -> String {
    let v = Json::parse(reply).expect("response must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(false)),
        "expected error response, got: {reply}"
    );
    v.get("error").and_then(|e| e.as_str()).unwrap().to_string()
}

fn obs_line(op: &str, id: u64, x: &[f32], c: f32) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(
        r#"{{"op":"{op}","id":{id},"x":[{}],"c":{c}}}"#,
        xs.join(",")
    )
}

#[test]
fn open_step_snapshot_restore_close_roundtrip() {
    let service = Service::new(2);
    // open
    let reply = service.handle_line(
        r#"{"op":"open","learner":"columnar:6","n_inputs":4,"alpha":0.005,"gamma":0.9,"lambda":0.95,"eps":0.01,"seed":11}"#,
    );
    let id = ok(&reply).get("id").unwrap().as_f64().unwrap() as u64;

    // step x N
    let mut rng = Xoshiro256::seed_from_u64(0);
    let mut last_y = 0.0;
    for _ in 0..300 {
        let x: Vec<f32> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let reply = service.handle_line(&obs_line("step", id, &x, 0.25));
        last_y = ok(&reply).get("y").unwrap().as_f64().unwrap();
    }
    assert!(last_y.is_finite());

    // snapshot
    let reply = service.handle_line(&format!(r#"{{"op":"snapshot","id":{id}}}"#));
    let state = ok(&reply).get("state").unwrap().clone();

    // restore -> a second, independent session with identical state
    let restore_req = Json::obj(vec![
        ("op", Json::Str("restore".into())),
        ("state", state),
    ]);
    let reply = service.handle_line(&restore_req.dump());
    let id2 = ok(&reply).get("id").unwrap().as_f64().unwrap() as u64;
    assert_ne!(id, id2);

    // both sessions must now evolve identically under identical input
    for _ in 0..200 {
        let x: Vec<f32> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let ya = ok(&service.handle_line(&obs_line("step", id, &x, -0.1)))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        let yb = ok(&service.handle_line(&obs_line("step", id2, &x, -0.1)))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(ya, yb, "restored session diverged from the original");
    }

    // close both; the original served 500 steps, the restore 300 + 200
    let reply = service.handle_line(&format!(r#"{{"op":"close","id":{id}}}"#));
    let steps = ok(&reply).get("steps").unwrap().as_f64().unwrap() as u64;
    assert_eq!(steps, 500);
    let reply = service.handle_line(&format!(r#"{{"op":"close","id":{id2}}}"#));
    let steps2 = ok(&reply).get("steps").unwrap().as_f64().unwrap() as u64;
    assert_eq!(steps2, 500, "snapshot carries the step count");

    // gone now
    let msg = err(&service.handle_line(&obs_line("step", id, &[0.0; 4], 0.0)));
    assert!(msg.contains("no session"), "{msg}");
}

#[test]
fn snapshot_restore_roundtrips_growing_ccn_sessions() {
    let service = Service::new(1);
    let reply = service.handle_line(
        r#"{"op":"open","learner":"ccn:6:2:100","n_inputs":3,"seed":5}"#,
    );
    let id = ok(&reply).get("id").unwrap().as_f64().unwrap() as u64;
    let mut rng = Xoshiro256::seed_from_u64(3);
    for _ in 0..150 {
        // crosses the first stage boundary at step 100
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        ok(&service.handle_line(&obs_line("step", id, &x, 0.1)));
    }
    let state = ok(&service.handle_line(&format!(r#"{{"op":"snapshot","id":{id}}}"#)))
        .get("state")
        .unwrap()
        .clone();
    let restore_req =
        Json::obj(vec![("op", Json::Str("restore".into())), ("state", state)]);
    let id2 = ok(&service.handle_line(&restore_req.dump()))
        .get("id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;
    // continue both across the next stage boundary (step 200)
    for _ in 0..120 {
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let ya = ok(&service.handle_line(&obs_line("step", id, &x, 0.1)))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        let yb = ok(&service.handle_line(&obs_line("step", id2, &x, 0.1)))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(ya, yb, "growing ccn session diverged after restore");
    }
}

#[test]
fn step_batch_matches_individual_steps() {
    let batched = Service::new(2);
    let singles = Service::new(2);
    let mut ids_a = Vec::new();
    let mut ids_b = Vec::new();
    for s in 0..6 {
        let open = format!(
            r#"{{"op":"open","learner":"columnar:4","n_inputs":2,"seed":{s}}}"#
        );
        ids_a.push(
            ok(&batched.handle_line(&open)).get("id").unwrap().as_f64().unwrap()
                as u64,
        );
        ids_b.push(
            ok(&singles.handle_line(&open)).get("id").unwrap().as_f64().unwrap()
                as u64,
        );
    }
    let mut rng = Xoshiro256::seed_from_u64(7);
    for _ in 0..40 {
        let xs: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..2).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let ids_json: Vec<String> = ids_a.iter().map(|i| i.to_string()).collect();
        let xs_json: Vec<String> = xs
            .iter()
            .map(|x| format!("[{},{}]", x[0], x[1]))
            .collect();
        let req = format!(
            r#"{{"op":"step_batch","ids":[{}],"xs":[{}],"cs":[0.1,0.1,0.1,0.1,0.1,0.1]}}"#,
            ids_json.join(","),
            xs_json.join(",")
        );
        let ys = ok(&batched.handle_line(&req));
        let ys = ys.get("ys").unwrap().as_arr().unwrap();
        for (k, (&id_b, x)) in ids_b.iter().zip(&xs).enumerate() {
            let y_single = ok(&singles.handle_line(&obs_line("step", id_b, x, 0.1)))
                .get("y")
                .unwrap()
                .as_f64()
                .unwrap();
            assert_eq!(
                ys[k].as_f64().unwrap(),
                y_single,
                "batched wire path diverged from single-step path"
            );
        }
    }
}

#[test]
fn protocol_errors_are_reported_not_fatal() {
    let service = Service::new(1);
    assert!(err(&service.handle_line("not json")).contains("bad json"));
    assert!(err(&service.handle_line(r#"{"op":"warp"}"#)).contains("unknown op"));
    assert!(err(&service.handle_line(r#"{"op":"step","id":99,"x":[1],"c":0}"#))
        .contains("no session"));
    // unknown learner kinds are refused with a useful message
    let msg = err(&service.handle_line(
        r#"{"op":"open","learner":"hopfield:4","n_inputs":2}"#,
    ));
    assert!(msg.contains("hopfield"), "{msg}");
    // the service survives all of the above
    ok(&service.handle_line(
        r#"{"op":"open","learner":"constructive:3:1000","n_inputs":2}"#,
    ));
    let stats = ok(&service.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(stats.get("sessions"), Some(&Json::Num(1.0)));
}

/// Every kind in the registry: `columnar:D`, `constructive:T:S`,
/// `ccn:T:P:S`, `tbptt:D:K`, `snap1:D` — all opened, stepped, snapshotted
/// and restored over the same JSONL protocol.
const ALL_KINDS: [(&str, &str); 5] = [
    ("columnar", "columnar:4"),
    ("constructive", "constructive:4:60"),
    ("ccn", "ccn:6:2:60"),
    ("tbptt", "tbptt:3:8"),
    ("snap1", "snap1:3"),
];

#[test]
fn every_kind_serves_over_the_wire_with_per_kind_stats() {
    let service = Service::new(2);
    let mut ids = Vec::new();
    for (_, spec) in ALL_KINDS {
        let open = format!(
            r#"{{"op":"open","learner":"{spec}","n_inputs":3,"seed":1}}"#
        );
        ids.push(
            ok(&service.handle_line(&open)).get("id").unwrap().as_f64().unwrap()
                as u64,
        );
    }
    let mut rng = Xoshiro256::seed_from_u64(2);
    for _ in 0..50 {
        for &id in &ids {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = ok(&service.handle_line(&obs_line("step", id, &x, 0.1)))
                .get("y")
                .unwrap()
                .as_f64()
                .unwrap();
            assert!(y.is_finite());
        }
    }
    let stats = ok(&service.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(stats.get("sessions"), Some(&Json::Num(5.0)));
    assert_eq!(stats.get("steps"), Some(&Json::Num(250.0)));
    let kinds = stats.get("kinds").expect("stats must report kinds");
    for (kind, _) in ALL_KINDS {
        assert_eq!(kinds.get(kind), Some(&Json::Num(1.0)), "kind {kind}");
    }
}

#[test]
fn prop_snapshot_restore_bit_exact_for_every_kind() {
    // property: for any registered kind, any seed and any split point,
    // snapshot -> restore -> N steps is bit-exact with the uninterrupted
    // session (the restored twin sees identical inputs).
    check("serve snapshot roundtrip", 3, |g| {
        let service = Service::new(2);
        for (kind, spec) in ALL_KINDS {
            let seed = g.usize_in(0, 1000);
            let warmup = g.usize_in(30, 150);
            let cont = g.usize_in(20, 120);
            let open = format!(
                r#"{{"op":"open","learner":"{spec}","n_inputs":3,"seed":{seed}}}"#
            );
            let id = ok(&service.handle_line(&open))
                .get("id")
                .unwrap()
                .as_f64()
                .unwrap() as u64;
            let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0xabcd);
            for _ in 0..warmup {
                let x: Vec<f32> =
                    (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
                ok(&service.handle_line(&obs_line("step", id, &x, 0.2)));
            }
            let state = ok(&service
                .handle_line(&format!(r#"{{"op":"snapshot","id":{id}}}"#)))
            .get("state")
            .unwrap()
            .clone();
            // the envelope is versioned and kind-tagged
            if state.get("v") != Some(&Json::Num(2.0)) {
                return Err(format!("{kind}: snapshot not v2: {state:?}"));
            }
            if state.get("kind").and_then(|k| k.as_str()) != Some(kind) {
                return Err(format!("{kind}: wrong kind tag in envelope"));
            }
            let restore =
                Json::obj(vec![("op", Json::Str("restore".into())), ("state", state)]);
            let id2 = ok(&service.handle_line(&restore.dump()))
                .get("id")
                .unwrap()
                .as_f64()
                .unwrap() as u64;
            for t in 0..cont {
                let x: Vec<f32> =
                    (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let ya = ok(&service.handle_line(&obs_line("step", id, &x, -0.1)))
                    .get("y")
                    .unwrap()
                    .as_f64()
                    .unwrap();
                let yb = ok(&service.handle_line(&obs_line("step", id2, &x, -0.1)))
                    .get("y")
                    .unwrap()
                    .as_f64()
                    .unwrap();
                if ya != yb {
                    return Err(format!(
                        "{kind}: diverged at step {t}: {ya} vs {yb}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn v1_ccn_snapshot_restores_through_the_wire_shim() {
    let service = Service::new(1);
    let id = ok(&service.handle_line(
        r#"{"op":"open","learner":"ccn:4:2:80","n_inputs":3,"seed":9}"#,
    ))
    .get("id")
    .unwrap()
    .as_f64()
    .unwrap() as u64;
    let mut rng = Xoshiro256::seed_from_u64(5);
    for _ in 0..120 {
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        ok(&service.handle_line(&obs_line("step", id, &x, 0.1)));
    }
    let state = ok(&service.handle_line(&format!(r#"{{"op":"snapshot","id":{id}}}"#)))
        .get("state")
        .unwrap()
        .clone();
    // rewrite the v2 envelope into PR 1's v1 shape: v = 1, no kind tag
    let v1 = match state {
        Json::Obj(mut o) => {
            o.insert("v".into(), Json::Num(1.0));
            o.remove("kind");
            Json::Obj(o)
        }
        other => panic!("snapshot must be an object, got {other:?}"),
    };
    let restore = Json::obj(vec![("op", Json::Str("restore".into())), ("state", v1)]);
    let id2 = ok(&service.handle_line(&restore.dump()))
        .get("id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;
    for _ in 0..80 {
        let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let ya = ok(&service.handle_line(&obs_line("step", id, &x, 0.1)))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        let yb = ok(&service.handle_line(&obs_line("step", id2, &x, 0.1)))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(ya, yb, "v1 shim restore diverged");
    }
}
