//! End-to-end tests of the durable session tier (`store` + serve
//! integration) over the JSONL wire protocol.
//!
//! The acceptance path: with `resident-cap = K`, opening 4x more
//! mixed-kind sessions than capacity and stepping them round-robin
//! produces predictions **bit-identical** to an unconstrained run (every
//! step churns sessions through evict -> park -> rehydrate), and a
//! kill/restart against the same store directory resumes every parked
//! session with no data loss.

use ccn_rtrl::serve::Service;
use ccn_rtrl::store::StoreConfig;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

fn ok(reply: &str) -> Json {
    let v = Json::parse(reply).expect("response must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(true)),
        "expected ok response, got: {reply}"
    );
    v
}

fn err(reply: &str) -> String {
    let v = Json::parse(reply).expect("response must be valid json");
    assert_eq!(
        v.get("ok"),
        Some(&Json::Bool(false)),
        "expected error response, got: {reply}"
    );
    v.get("error").and_then(|e| e.as_str()).unwrap().to_string()
}

fn step_line(id: u64, x: &[f32], c: f32) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(r#"{{"op":"step","id":{id},"x":[{}],"c":{c}}}"#, xs.join(","))
}

fn step_y(service: &Service, id: u64, x: &[f32], c: f32) -> f64 {
    ok(&service.handle_line(&step_line(id, x, c)))
        .get("y")
        .unwrap()
        .as_f64()
        .unwrap()
}

fn open_id(service: &Service, spec: &str, seed: u64) -> u64 {
    let line = format!(
        r#"{{"op":"open","learner":"{spec}","n_inputs":3,"seed":{seed}}}"#
    );
    ok(&service.handle_line(&line)).get("id").unwrap().as_f64().unwrap() as u64
}

fn num(v: &Json, key: &str) -> f64 {
    v.get(key).unwrap().as_f64().unwrap()
}

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    std::env::temp_dir().join(format!(
        "ccn-store-e2e-{tag}-{}-{nanos}",
        std::process::id()
    ))
}

/// All five registered kinds, cycled across the session population.
const KINDS: [&str; 5] = [
    "columnar:4",
    "constructive:4:60",
    "ccn:6:2:60",
    "tbptt:3:8",
    "snap1:3",
];

/// The ISSUE acceptance test: cap K per shard, 4x oversubscription,
/// mixed kinds, round-robin stepping — bit-identical to an unconstrained
/// twin — then a kill (drop without close) with everything parked and a
/// restart against the same store dir that loses nothing.
#[test]
fn churn_is_bit_identical_to_unconstrained_and_survives_restart() {
    let dir = fresh_dir("churn");
    let shards = 2;
    let cap = 2; // resident capacity 4 total; 16 sessions = 4x
    let n_sessions = 16u64;
    let constrained =
        Service::with_store(shards, Some(StoreConfig::new(&dir, cap))).unwrap();
    let unconstrained = Service::new(shards);

    let mut ids = Vec::new();
    for s in 0..n_sessions {
        let spec = KINDS[s as usize % KINDS.len()];
        let a = open_id(&constrained, spec, s);
        let b = open_id(&unconstrained, spec, s);
        assert_eq!(a, b, "both services must allocate identical ids");
        ids.push(a);
    }

    let mut rng = Xoshiro256::seed_from_u64(0x570e);
    let mut drive = |constrained: &Service, ticks: usize| {
        for _ in 0..ticks {
            for &id in &ids {
                let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let c = rng.uniform(-0.5, 0.5);
                let ya = step_y(constrained, id, &x, c);
                let yb = step_y(&unconstrained, id, &x, c);
                assert_eq!(
                    ya, yb,
                    "constrained run diverged from unconstrained (id {id})"
                );
            }
        }
    };
    // phase 1: heavy churn (every step evicts someone and rehydrates the
    // target), across constructive/ccn stage boundaries at step 60
    drive(&constrained, 40);
    let stats = ok(&constrained.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(num(&stats, "sessions") as u64, n_sessions);
    assert_eq!(num(&stats, "resident") as u64, shards as u64 * cap as u64);
    assert_eq!(
        num(&stats, "parked") as u64,
        n_sessions - shards as u64 * cap as u64
    );
    assert!(num(&stats, "evictions") > 0.0);
    assert!(num(&stats, "rehydrations") > 0.0);
    assert!(num(&stats, "store_bytes") > 0.0);

    // phase 2: park everything, then kill (drop without close)
    for &id in &ids {
        ok(&constrained.handle_line(&format!(r#"{{"op":"park","id":{id}}}"#)));
    }
    drop(constrained);

    // phase 3: restart against the same store dir — every session
    // resumes with its exact state
    let constrained =
        Service::with_store(shards, Some(StoreConfig::new(&dir, cap))).unwrap();
    let stats = ok(&constrained.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(num(&stats, "sessions") as u64, n_sessions, "no data loss");
    assert_eq!(num(&stats, "resident"), 0.0);
    assert_eq!(num(&stats, "parked") as u64, n_sessions);
    let kinds = stats.get("kinds").unwrap();
    for kind in ["columnar", "tbptt", "snap1"] {
        assert!(
            kinds.get(kind).and_then(|n| n.as_f64()).unwrap_or(0.0) > 0.0,
            "restart must report parked kind {kind}"
        );
    }
    drive(&constrained, 25);

    // closing a session reports the full step count across both lives
    let reply =
        ok(&constrained.handle_line(&format!(r#"{{"op":"close","id":{}}}"#, ids[0])));
    assert_eq!(num(&reply, "steps") as u64, 65);
    let msg = err(&constrained.handle_line(&step_line(ids[0], &[0.0; 3], 0.0)));
    assert!(msg.contains("no session"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite: evict -> rehydrate is bit-exact for each of the five kinds
/// individually — step N, force eviction via the wire `park` op, step M
/// more against a never-evicted twin, step for step.
#[test]
fn evict_rehydrate_is_bit_exact_for_every_kind() {
    let dir = fresh_dir("kinds");
    let service =
        Service::with_store(1, Some(StoreConfig::new(&dir, 0))).unwrap();
    let twin = Service::new(1);
    for (k, spec) in KINDS.iter().enumerate() {
        let id_a = open_id(&service, spec, 100 + k as u64);
        let id_b = open_id(&twin, spec, 100 + k as u64);
        let mut rng = Xoshiro256::seed_from_u64(k as u64 ^ 0xeeee);
        // step N: past the first constructive/ccn stage boundary
        for _ in 0..80 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            assert_eq!(
                step_y(&service, id_a, &x, c),
                step_y(&twin, id_b, &x, c),
                "{spec} diverged before eviction"
            );
        }
        // force eviction; the next step transparently rehydrates
        let parked =
            ok(&service.handle_line(&format!(r#"{{"op":"park","id":{id_a}}}"#)));
        assert_eq!(parked.get("parked"), Some(&Json::Bool(true)));
        // a snapshot of a parked session comes straight from the store
        let snap =
            ok(&service.handle_line(&format!(r#"{{"op":"snapshot","id":{id_a}}}"#)));
        assert_eq!(
            snap.get("state").unwrap().get("v"),
            Some(&Json::Num(2.0)),
            "{spec}: parked snapshot must be the v2 envelope"
        );
        // step M: crosses the *next* stage boundary for growing kinds
        for t in 0..100 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            assert_eq!(
                step_y(&service, id_a, &x, c),
                step_y(&twin, id_b, &x, c),
                "{spec} diverged at step {t} after rehydration"
            );
        }
        // explicit warm on an already-resident session is a no-op
        let warm =
            ok(&service.handle_line(&format!(r#"{{"op":"warm","id":{id_a}}}"#)));
        assert_eq!(warm.get("rehydrated"), Some(&Json::Bool(false)));
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Kill semantics: sessions that were only resident (never parked) die
/// with the process; parked sessions survive. The restarted service
/// reports exactly the parked population.
#[test]
fn kill_preserves_parked_sessions_only() {
    let dir = fresh_dir("kill");
    let cfg = StoreConfig::new(&dir, 0);
    let (id_parked, id_lost);
    {
        let service = Service::with_store(1, Some(cfg.clone())).unwrap();
        id_parked = open_id(&service, "columnar:4", 1);
        id_lost = open_id(&service, "tbptt:3:8", 2);
        for id in [id_parked, id_lost] {
            for _ in 0..10 {
                step_y(&service, id, &[0.1, -0.2, 0.3], 0.1);
            }
        }
        ok(&service.handle_line(&format!(r#"{{"op":"park","id":{id_parked}}}"#)));
        // dropped without close(): the crash path
    }
    let service = Service::with_store(1, Some(cfg)).unwrap();
    let stats = ok(&service.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(num(&stats, "sessions"), 1.0);
    let y = step_y(&service, id_parked, &[0.1, -0.2, 0.3], 0.1);
    assert!(y.is_finite());
    let msg = err(&service.handle_line(&step_line(id_lost, &[0.0; 3], 0.0)));
    assert!(msg.contains("no session"), "{msg}");
    // new ids never collide with *any* pre-crash id: parked survivors
    // are covered by the boot scan, and never-parked casualties by the
    // persisted next-id watermark
    let fresh = open_id(&service, "snap1:3", 9);
    assert!(fresh > id_parked, "fresh id {fresh} collides with survivor");
    assert!(fresh > id_lost, "fresh id {fresh} reuses a dead session's id");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Regression (ROADMAP fix): before the persisted next-id watermark, a
/// crash forgot every id that was never parked — the next boot started
/// the allocator just above the highest *parked* id, so a client still
/// holding a pre-crash id could silently end up talking to a stranger's
/// fresh session. Now every handed-out id is durably burned first.
#[test]
fn next_id_watermark_survives_kill_without_any_parks() {
    let dir = fresh_dir("watermark");
    let cfg = StoreConfig::new(&dir, 0);
    let mut pre_crash = Vec::new();
    {
        let service = Service::with_store(2, Some(cfg.clone())).unwrap();
        for s in 0..5u64 {
            let id = open_id(&service, KINDS[s as usize % KINDS.len()], s);
            step_y(&service, id, &[0.1, 0.2, 0.3], 0.1);
            pre_crash.push(id);
        }
        // dropped without close(): nothing was ever parked, the store
        // segments are empty — only the watermark knows these ids
    }
    let service = Service::with_store(2, Some(cfg.clone())).unwrap();
    let stats = ok(&service.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(num(&stats, "sessions"), 0.0, "nothing parked, nothing resumes");
    let max_pre = *pre_crash.iter().max().unwrap();
    for s in 0..5u64 {
        let fresh = open_id(&service, "columnar:4", 100 + s);
        assert!(
            fresh > max_pre,
            "post-crash id {fresh} reuses a pre-crash id (max was {max_pre})"
        );
    }
    drop(service);
    // a second crash/restart cycle keeps the floor monotone
    let service = Service::with_store(2, Some(cfg)).unwrap();
    let again = open_id(&service, "snap1:3", 7);
    assert!(again > max_pre, "watermark floor regressed to {again}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Graceful shutdown flushes resident sessions without an explicit park;
/// the restarted service continues them bit-identically.
#[test]
fn graceful_close_flushes_everything() {
    let dir = fresh_dir("grace");
    let cfg = StoreConfig::new(&dir, 0);
    let twin = Service::new(2);
    let mut service = Service::with_store(2, Some(cfg.clone())).unwrap();
    let mut ids = Vec::new();
    let mut rng = Xoshiro256::seed_from_u64(0xace);
    for s in 0..6u64 {
        let spec = KINDS[s as usize % KINDS.len()];
        let a = open_id(&service, spec, s);
        assert_eq!(a, open_id(&twin, spec, s));
        ids.push(a);
    }
    for _ in 0..30 {
        for &id in &ids {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            assert_eq!(step_y(&service, id, &x, c), step_y(&twin, id, &x, c));
        }
    }
    assert_eq!(
        service.close().unwrap(),
        6,
        "close must flush every resident session"
    );
    drop(service);
    let service = Service::with_store(2, Some(cfg)).unwrap();
    for _ in 0..20 {
        for &id in &ids {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            assert_eq!(
                step_y(&service, id, &x, c),
                step_y(&twin, id, &x, c),
                "flushed session {id} diverged after restart"
            );
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Capacity-overflow churn: the resident SoA batch's padded arrays must
/// double through several grow steps while LRU eviction churns sessions
/// through the store, then shrink again (compaction) as the population
/// drains — bit-identical to an unconstrained twin throughout.
#[test]
fn batch_capacity_growth_and_compaction_stay_bit_exact() {
    let dir = fresh_dir("grow");
    // one shard, resident cap 12: 16 columnar sessions oversubscribe it,
    // so the batch grows 0 -> 4 -> 8 -> 16 *while* evict/rehydrate churn
    // swap-removes and re-pushes lanes on almost every step
    let constrained =
        Service::with_store(1, Some(StoreConfig::new(&dir, 12))).unwrap();
    let unconstrained = Service::new(1);
    let mut ids = Vec::new();
    let open_both = |s: u64| {
        let a = open_id(&constrained, "columnar:4", s);
        let b = open_id(&unconstrained, "columnar:4", s);
        assert_eq!(a, b, "both services must allocate identical ids");
        a
    };
    let mut rng = Xoshiro256::seed_from_u64(0x9409);
    let drive = |ids: &[u64], rng: &mut Xoshiro256, ticks: usize| {
        for _ in 0..ticks {
            for &id in ids {
                let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let c = rng.uniform(-0.5, 0.5);
                assert_eq!(
                    step_y(&constrained, id, &x, c),
                    step_y(&unconstrained, id, &x, c),
                    "constrained run diverged (id {id})"
                );
            }
        }
    };
    // wave 1: a small population, batch capacity settles at 4
    for s in 0..3u64 {
        ids.push(open_both(s));
    }
    drive(&ids, &mut rng, 10);
    // wave 2: 13 more sessions force capacity doublings under live churn
    for s in 3..16u64 {
        ids.push(open_both(s));
    }
    drive(&ids, &mut rng, 15);
    let stats = ok(&constrained.handle_line(r#"{"op":"stats"}"#));
    assert!(num(&stats, "evictions") > 0.0, "cap 12 must have churned");
    assert!(num(&stats, "rehydrations") > 0.0);
    // wave 3: close 13 of 16 on both services — repeated swap-removes
    // plus the <=1/4-occupancy compaction of the padded arrays
    for &id in &ids[..13] {
        ok(&constrained.handle_line(&format!(r#"{{"op":"close","id":{id}}}"#)));
        ok(&unconstrained.handle_line(&format!(r#"{{"op":"close","id":{id}}}"#)));
    }
    drive(&ids[13..], &mut rng, 10);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Store ops degrade cleanly without a mounted store, and park/warm
/// report missing sessions with useful errors when one is mounted.
#[test]
fn store_ops_error_cleanly() {
    let storeless = Service::new(1);
    let id = open_id(&storeless, "columnar:4", 0);
    let msg = err(&storeless.handle_line(&format!(r#"{{"op":"park","id":{id}}}"#)));
    assert!(msg.contains("store"), "{msg}");
    // the session is untouched by the failed park
    assert!(step_y(&storeless, id, &[0.0; 3], 0.0).is_finite());
    let stats = ok(&storeless.handle_line(r#"{"op":"stats"}"#));
    assert_eq!(num(&stats, "parked"), 0.0);
    assert_eq!(num(&stats, "store_bytes"), 0.0);

    let dir = fresh_dir("errs");
    let service =
        Service::with_store(1, Some(StoreConfig::new(&dir, 0))).unwrap();
    let msg = err(&service.handle_line(r#"{"op":"park","id":404}"#));
    assert!(msg.contains("no session"), "{msg}");
    let msg = err(&service.handle_line(r#"{"op":"warm","id":404}"#));
    assert!(msg.contains("no session"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}
