//! End-to-end tests of the TCP/UDS transport: concurrent clients drive
//! the serve protocol over real sockets.
//!
//! The acceptance soak: 8 concurrent TCP clients, each owning several
//! mixed-kind sessions, step them through a live listener; afterwards
//! every session's snapshot is **bit-identical** to a single-threaded
//! stdio replay of the same per-session op sequence. Plus: UDS
//! roundtrip, `--max-conns` refusal, disconnect cleanup, per-connection
//! stats tagging, and a store-backed shutdown/restart (flush + id
//! watermark) over the wire.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::sync::{Arc, Barrier};

use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::store::StoreConfig;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

const KINDS: [&str; 5] = [
    "columnar:4",
    "constructive:4:60",
    "ccn:6:2:60",
    "tbptt:3:8",
    "snap1:3",
];

/// A blocking JSONL client: one call = one request line, one reply line.
struct Client<S: Read + Write> {
    reader: BufReader<S>,
    writer: S,
}

impl Client<TcpStream> {
    /// Connect to a [`Server::local_addr`] string (`tcp://HOST:PORT`).
    fn connect_tcp(local: &str) -> Client<TcpStream> {
        let hostport = local.strip_prefix("tcp://").expect("tcp local addr");
        let stream = TcpStream::connect(hostport).expect("connect");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: stream,
        }
    }
}

impl Client<UnixStream> {
    fn connect_unix(path: &std::path::Path) -> Client<UnixStream> {
        let stream = UnixStream::connect(path).expect("connect uds");
        Client {
            reader: BufReader::new(stream.try_clone().expect("clone")),
            writer: stream,
        }
    }
}

impl<S: Read + Write> Client<S> {
    fn call(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").expect("send");
        self.writer.flush().expect("flush");
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("recv");
        assert!(
            reply.ends_with('\n'),
            "reply must be one full line, got {reply:?}"
        );
        Json::parse(reply.trim()).expect("reply must be valid json")
    }

    fn call_ok(&mut self, line: &str) -> Json {
        let v = self.call(line);
        assert_eq!(
            v.get("ok"),
            Some(&Json::Bool(true)),
            "expected ok reply to {line}: {v:?}"
        );
        v
    }

    fn open(&mut self, spec: &str, seed: u64) -> u64 {
        let line = format!(
            r#"{{"op":"open","learner":"{spec}","n_inputs":3,"seed":{seed}}}"#
        );
        self.call_ok(&line).get("id").unwrap().as_f64().unwrap() as u64
    }
}

/// The shared step-line builder: the soak client and the stdio replay
/// must format observations identically so the comparison is bit-exact.
fn step_line(id: u64, x: &[f32], c: f32) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!(r#"{{"op":"step","id":{id},"x":[{}],"c":{c}}}"#, xs.join(","))
}

/// One session's pre-generated workload (ids are assigned at open time,
/// so only the raw observations are fixed up front).
struct SessionPlan {
    spec: &'static str,
    seed: u64,
    steps: Vec<(Vec<f32>, f32)>,
}

fn make_plan(spec: &'static str, seed: u64, n_steps: usize) -> SessionPlan {
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x50a1);
    let steps = (0..n_steps)
        .map(|_| {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (x, rng.uniform(-0.5, 0.5))
        })
        .collect();
    SessionPlan { spec, seed, steps }
}

/// The ISSUE acceptance test: >= 8 concurrent TCP clients, mixed kinds,
/// results bit-identical to a single-threaded stdio replay.
#[test]
fn tcp_soak_8_clients_bit_identical_to_stdio_replay() {
    const CLIENTS: usize = 8;
    const SESSIONS_PER_CLIENT: usize = 3;
    const STEPS: usize = 40;

    let server = Server::bind(
        Service::new(3),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let local = server.local_addr().to_string();

    let barrier = Arc::new(Barrier::new(CLIENTS));
    let mut joins = Vec::new();
    for k in 0..CLIENTS {
        let local = local.clone();
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect_tcp(&local);
            let plans: Vec<SessionPlan> = (0..SESSIONS_PER_CLIENT)
                .map(|j| {
                    let n = k * SESSIONS_PER_CLIENT + j;
                    make_plan(KINDS[n % KINDS.len()], 1000 + n as u64, STEPS)
                })
                .collect();
            let ids: Vec<u64> = plans
                .iter()
                .map(|p| client.open(p.spec, p.seed))
                .collect();
            // all clients are connected with sessions open: one client
            // observes the full concurrency through `stats`
            barrier.wait();
            if k == 0 {
                let stats = client.call_ok(r#"{"op":"stats"}"#);
                let transport = stats.get("transport").expect("transport block");
                assert_eq!(
                    transport.get("active_conns"),
                    Some(&Json::Num(CLIENTS as f64)),
                    "soak must run {CLIENTS} concurrent clients: {transport:?}"
                );
                assert_eq!(
                    transport.get("conns").unwrap().as_arr().unwrap().len(),
                    CLIENTS
                );
            }
            barrier.wait();
            // interleave this client's sessions round-robin; replies are
            // strictly in request order (one in flight per connection)
            for t in 0..STEPS {
                for (p, &id) in plans.iter().zip(&ids) {
                    let (x, c) = &p.steps[t];
                    let y = client
                        .call_ok(&step_line(id, x, *c))
                        .get("y")
                        .unwrap()
                        .as_f64()
                        .unwrap();
                    assert!(y.is_finite());
                }
            }
            plans
                .iter()
                .zip(&ids)
                .map(|(p, &id)| {
                    let snap = client
                        .call_ok(&format!(r#"{{"op":"snapshot","id":{id}}}"#))
                        .get("state")
                        .unwrap()
                        .clone();
                    (p.spec, p.seed, p.steps.clone(), snap)
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut outcomes = Vec::new();
    for join in joins {
        outcomes.extend(join.join().expect("client thread panicked"));
    }
    assert_eq!(server.shutdown().unwrap(), 0, "storeless server flushes nothing");

    // single-threaded stdio replay of every per-session op sequence
    let replay = Service::new(1);
    for (spec, seed, steps, transported) in outcomes {
        let open = format!(
            r#"{{"op":"open","learner":"{spec}","n_inputs":3,"seed":{seed}}}"#
        );
        let v = Json::parse(&replay.handle_line(&open)).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{v:?}");
        let id = v.get("id").unwrap().as_f64().unwrap() as u64;
        for (x, c) in &steps {
            let r = Json::parse(&replay.handle_line(&step_line(id, x, *c))).unwrap();
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        }
        let r = Json::parse(
            &replay.handle_line(&format!(r#"{{"op":"snapshot","id":{id}}}"#)),
        )
        .unwrap();
        let replayed = r.get("state").unwrap();
        assert_eq!(
            &transported, replayed,
            "session (spec {spec}, seed {seed}) is not bit-identical to \
             its stdio replay"
        );
    }
}

#[test]
fn uds_roundtrip_serves_the_full_protocol() {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let path = std::env::temp_dir()
        .join(format!("ccn-uds-{}-{nanos}.sock", std::process::id()));
    let server = Server::bind(
        Service::new(2),
        &ListenAddr::Unix(path.clone()),
        0,
    )
    .unwrap();
    let mut client = Client::connect_unix(&path);
    let id = client.open("tbptt:3:8", 4);
    for _ in 0..20 {
        let y = client
            .call_ok(&step_line(id, &[0.1, -0.2, 0.3], 0.25))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(y.is_finite());
    }
    let snap = client
        .call_ok(&format!(r#"{{"op":"snapshot","id":{id}}}"#))
        .get("state")
        .unwrap()
        .clone();
    assert_eq!(snap.get("kind").and_then(|k| k.as_str()), Some("tbptt"));
    let restore =
        Json::obj(vec![("op", Json::Str("restore".into())), ("state", snap)]);
    let id2 = client
        .call_ok(&restore.dump())
        .get("id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;
    // original and restore answer identically through the socket
    for _ in 0..20 {
        let ya = client.call_ok(&step_line(id, &[0.3, 0.1, -0.4], 0.0));
        let yb = client.call_ok(&step_line(id2, &[0.3, 0.1, -0.4], 0.0));
        assert_eq!(ya.get("y"), yb.get("y"));
    }
    let stats = client.call_ok(r#"{"op":"stats"}"#);
    assert_eq!(stats.get("sessions"), Some(&Json::Num(2.0)));
    let transport = stats.get("transport").unwrap();
    assert_eq!(transport.get("active_conns"), Some(&Json::Num(1.0)));
    client.call_ok(&format!(r#"{{"op":"close","id":{id2}}}"#));
    server.shutdown().unwrap();
    assert!(!path.exists(), "shutdown must remove the socket file");
}

#[test]
fn max_conns_refuses_with_an_error_line_then_recovers() {
    let server = Server::bind(
        Service::new(1),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        1,
    )
    .unwrap();
    let local = server.local_addr().to_string();
    let mut first = Client::connect_tcp(&local);
    // a full round trip proves the first client is accepted + registered
    first.call_ok(r#"{"op":"stats"}"#);

    let hostport = local.strip_prefix("tcp://").unwrap();
    let refused = TcpStream::connect(hostport).unwrap();
    let mut reader = BufReader::new(refused);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
    assert!(
        v.get("error").and_then(|e| e.as_str()).unwrap().contains("max-conns"),
        "{v:?}"
    );
    // the refused socket is closed after the error line
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "expected EOF");

    // the refusal is counted, and the first client is unharmed
    let stats = first.call_ok(r#"{"op":"stats"}"#);
    let transport = stats.get("transport").unwrap();
    assert_eq!(transport.get("refused"), Some(&Json::Num(1.0)));
    assert_eq!(transport.get("max_conns"), Some(&Json::Num(1.0)));

    // freeing the slot lets a new client in (poll: deregistration races
    // the accept loop, and a refused socket may die mid-roundtrip)
    drop(first);
    let mut admitted = None;
    for _ in 0..200 {
        let stream = TcpStream::connect(hostport).unwrap();
        let mut c = Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        };
        let sent = writeln!(c.writer, r#"{{"op":"stats"}}"#)
            .and_then(|()| c.writer.flush())
            .is_ok();
        let mut line = String::new();
        if sent && c.reader.read_line(&mut line).unwrap_or(0) > 0 {
            if let Ok(v) = Json::parse(line.trim()) {
                if v.get("ok") == Some(&Json::Bool(true)) {
                    admitted = Some(c);
                    break;
                }
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let mut c = admitted.expect("a freed slot must admit a new client");
    c.call_ok(r#"{"op":"stats"}"#);
    server.shutdown().unwrap();
}

/// A client that streams far past the request-line cap (16MB) without a
/// newline must get exactly one error reply once the line finally ends —
/// with the excess drained, not buffered — and the connection (and
/// server) must keep working afterwards.
#[test]
fn overlong_line_is_drained_with_one_error_and_the_conn_survives() {
    let server = Server::bind(
        Service::new(1),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let mut client = Client::connect_tcp(&server.local_addr().to_string());
    let chunk = vec![b'a'; 1 << 20];
    for _ in 0..17 {
        client.writer.write_all(&chunk).unwrap();
    }
    client.writer.write_all(b"\n").unwrap();
    client.writer.flush().unwrap();
    let mut reply = String::new();
    client.reader.read_line(&mut reply).unwrap();
    let v = Json::parse(reply.trim()).unwrap();
    assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert!(
        v.get("error").and_then(|e| e.as_str()).unwrap().contains("exceeds"),
        "{reply}"
    );
    // same connection, next line: business as usual
    let id = client.open("columnar:4", 1);
    let y = client
        .call_ok(&step_line(id, &[0.1, 0.2, 0.3], 0.5))
        .get("y")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(y.is_finite());
    server.shutdown().unwrap();
}

#[test]
fn disconnect_frees_the_connection_but_not_the_sessions() {
    let server = Server::bind(
        Service::new(1),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let local = server.local_addr().to_string();
    let mut keeper = Client::connect_tcp(&local);
    let id = {
        let mut ephemeral = Client::connect_tcp(&local);
        let id = ephemeral.open("columnar:4", 7);
        ephemeral
            .call_ok(&step_line(id, &[0.1, 0.2, 0.3], 0.5));
        id
        // ephemeral drops here: EOF on the server's reader
    };
    // the connection deregisters (poll for the reader to notice EOF)...
    let mut active = usize::MAX;
    for _ in 0..200 {
        let stats = keeper.call_ok(r#"{"op":"stats"}"#);
        let transport = stats.get("transport").unwrap();
        active = transport.get("active_conns").unwrap().as_f64().unwrap() as usize;
        if active == 1 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(active, 1, "dropped client must deregister");
    // ...but the session it opened is server-owned and lives on
    let y = keeper
        .call_ok(&step_line(id, &[0.1, 0.2, 0.3], 0.5))
        .get("y")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(y.is_finite());
    server.shutdown().unwrap();
}

/// Store-backed server over TCP: shutdown flushes every session; a
/// restarted listener on the same store resumes them, and the persisted
/// id watermark keeps post-restart ids collision-free.
#[test]
fn shutdown_flush_and_watermark_survive_a_transport_restart() {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let dir = std::env::temp_dir().join(format!(
        "ccn-transport-store-{}-{nanos}",
        std::process::id()
    ));
    let cfg = StoreConfig::new(&dir, 0);

    let server = Server::bind(
        Service::with_store(2, Some(cfg.clone())).unwrap(),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let mut client = Client::connect_tcp(&server.local_addr().to_string());
    let mut ids = Vec::new();
    for s in 0..3u64 {
        let id = client.open(KINDS[s as usize % KINDS.len()], s);
        for _ in 0..10 {
            client.call_ok(&step_line(id, &[0.2, -0.1, 0.4], 0.1));
        }
        ids.push(id);
    }
    drop(client);
    assert_eq!(server.shutdown().unwrap(), 3, "shutdown must flush all three");

    let server = Server::bind(
        Service::with_store(2, Some(cfg)).unwrap(),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let mut client = Client::connect_tcp(&server.local_addr().to_string());
    let stats = client.call_ok(r#"{"op":"stats"}"#);
    assert_eq!(stats.get("sessions"), Some(&Json::Num(3.0)));
    assert_eq!(stats.get("parked"), Some(&Json::Num(3.0)));
    // parked sessions step (transparent rehydration) through the socket
    for &id in &ids {
        let y = client
            .call_ok(&step_line(id, &[0.0, 0.1, -0.2], 0.0))
            .get("y")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(y.is_finite());
    }
    // the id watermark started above every pre-restart id
    let fresh = client.open("snap1:3", 50);
    assert!(
        fresh > *ids.iter().max().unwrap(),
        "post-restart id {fresh} collides with a pre-restart session"
    );
    drop(client);
    assert_eq!(server.shutdown().unwrap(), 4);
    std::fs::remove_dir_all(&dir).unwrap();
}
