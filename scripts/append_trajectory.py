#!/usr/bin/env python3
"""Gate a fresh bench record against the committed perf trajectory.

Usage: append_trajectory.py FRESH.json TRAJECTORY_DIR [--copy-to DIR]

TRAJECTORY_DIR holds dated, committed `BENCH_*.json` snapshots (schema
ccn.bench.v1), possibly for several different benches. The baseline is
the latest snapshot *of the same bench* as FRESH (matching top-level
`bench` fields; lexicographic order sorts by date for
`BENCH_YYYYMMDD_*` names). Every `steps_per_s` leaf shared by the
baseline and FRESH is compared: the fresh value must be at least HALF
the committed one (a >2x regression fails). Paths present on only one
side are reported but not gated, so adding or dropping a bench phase is
not a CI failure.

--copy-to DIR copies FRESH into DIR as `BENCH_<utcdate>_<name>` so the
CI run's own snapshot can be uploaded as an artifact (and later
committed as the next trajectory point).

Stdlib only; exits non-zero naming the regressed path on failure.
"""

import json
import os
import shutil
import sys
import time

SCHEMA = "ccn.bench.v1"
GATE = 0.5  # fresh must reach at least this fraction of the baseline


def fail(msg):
    print(f"append_trajectory: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")
    if doc.get("schema") != SCHEMA:
        fail(f"{path}: missing or wrong schema tag (want {SCHEMA!r}, "
             f"got {doc.get('schema')!r})")
    return doc


def steps_per_s_leaves(node, where="$"):
    """{json_path: value} for every numeric `steps_per_s` key."""
    leaves = {}
    if isinstance(node, dict):
        for key, child in node.items():
            if key == "steps_per_s" and isinstance(child, (int, float)):
                leaves[f"{where}.{key}"] = float(child)
            else:
                leaves.update(steps_per_s_leaves(child, f"{where}.{key}"))
    elif isinstance(node, list):
        for i, child in enumerate(node):
            leaves.update(steps_per_s_leaves(child, f"{where}[{i}]"))
    return leaves


def main(argv):
    copy_to = None
    if "--copy-to" in argv:
        i = argv.index("--copy-to")
        if i + 1 >= len(argv):
            fail("--copy-to needs a directory")
        copy_to = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    if len(argv) != 3:
        fail("usage: append_trajectory.py FRESH.json TRAJECTORY_DIR "
             "[--copy-to DIR]")
    fresh_path, traj_dir = argv[1], argv[2]
    fresh = load(fresh_path)

    snapshots = sorted(
        name for name in os.listdir(traj_dir)
        if name.startswith("BENCH_") and name.endswith(".json")
    )
    if not snapshots:
        fail(f"{traj_dir}: no committed BENCH_*.json snapshots")
    # baseline: the latest committed snapshot of the *same* bench — the
    # trajectory dir gates several benches side by side
    baseline_path = baseline = None
    for name in snapshots:
        candidate = load(os.path.join(traj_dir, name))
        if candidate.get("bench") == fresh.get("bench"):
            baseline_path = os.path.join(traj_dir, name)
            baseline = candidate
    if baseline is None:
        fail(f"{traj_dir}: no committed snapshot for bench "
             f"{fresh.get('bench')!r} (have {snapshots})")

    want = steps_per_s_leaves(baseline)
    got = steps_per_s_leaves(fresh)
    if not want:
        fail(f"{baseline_path}: no steps_per_s leaves to gate against")
    shared = sorted(set(want) & set(got))
    if not shared:
        fail(f"{fresh_path}: no steps_per_s leaf matches the baseline "
             f"{baseline_path} (baseline has {sorted(want)})")
    for path in sorted(set(want) ^ set(got)):
        side = "baseline only" if path in want else "fresh only"
        print(f"append_trajectory: note: {path} is {side}; not gated")
    for path in shared:
        floor = want[path] * GATE
        if got[path] < floor:
            fail(f"{path}: {got[path]:.1f} steps/s is a >2x regression "
                 f"from the committed {want[path]:.1f} "
                 f"(floor {floor:.1f}, baseline {baseline_path})")
        print(f"append_trajectory: {path}: {got[path]:.1f} steps/s "
              f"(committed {want[path]:.1f}, floor {floor:.1f}) ok")

    if copy_to:
        os.makedirs(copy_to, exist_ok=True)
        date = time.strftime("%Y%m%d", time.gmtime())
        base = os.path.basename(fresh_path)
        name = base[len("BENCH_"):] if base.startswith("BENCH_") else base
        dest = os.path.join(copy_to, f"BENCH_{date}_{name}")
        shutil.copyfile(fresh_path, dest)
        print(f"append_trajectory: copied snapshot to {dest}")

    print(f"append_trajectory: ok ({len(shared)} gated leaf/leaves vs "
          f"{baseline_path})")


if __name__ == "__main__":
    main(sys.argv)
