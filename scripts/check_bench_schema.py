#!/usr/bin/env python3
"""Validate unified bench JSON artifacts (schema ccn.bench.v1).

Usage: check_bench_schema.py BENCH_a.json [BENCH_b.json ...]

Each file must carry the top-level schema tag and bench name, and every
embedded latency histogram (the obs::HistogramSnapshot::to_json shape,
recognized by its count/sum_ns/buckets keys) must be internally
consistent: count equals the sum of bucket counts, bucket lower bounds
strictly ascend, every listed bucket count is positive, the percentile
ladder is monotone between min and max, and an empty histogram carries
no buckets. At least one histogram must be present per file — a bench
that stops embedding latency data should fail CI, not silently pass.

Stdlib only; exits non-zero with a message naming the offending file
and JSON path on the first violation.
"""

import json
import sys

SCHEMA = "ccn.bench.v1"
HIST_KEYS = {"count", "sum_ns", "buckets"}
LADDER = ["min_ns", "p50_ns", "p90_ns", "p99_ns", "p999_ns", "max_ns"]


def fail(msg):
    print(f"check_bench_schema: {msg}", file=sys.stderr)
    sys.exit(1)


def check_histogram(path, where, h):
    for key in sorted(HIST_KEYS | set(LADDER)):
        if key not in h:
            fail(f"{path}: {where}: histogram missing key {key!r}")
    count = h["count"]
    buckets = h["buckets"]
    if not isinstance(buckets, list):
        fail(f"{path}: {where}: buckets must be a list")
    total = 0
    prev_lo = -1
    for i, pair in enumerate(buckets):
        if not (isinstance(pair, list) and len(pair) == 2):
            fail(f"{path}: {where}: buckets[{i}] must be a [lo_ns, count] pair")
        lo, n = pair
        if lo <= prev_lo:
            fail(f"{path}: {where}: bucket bounds must ascend ({lo} after {prev_lo})")
        if n <= 0:
            fail(f"{path}: {where}: buckets[{i}] has non-positive count {n}")
        prev_lo = lo
        total += n
    if total != count:
        fail(f"{path}: {where}: count {count} != sum of bucket counts {total}")
    if count == 0 and buckets:
        fail(f"{path}: {where}: empty histogram must carry no buckets")
    if count > 0:
        values = [h[k] for k in LADDER]
        for a, b in zip(values, values[1:]):
            if a > b:
                fail(
                    f"{path}: {where}: percentile ladder not monotone: "
                    + ", ".join(f"{k}={h[k]}" for k in LADDER)
                )


def walk(path, where, node, found):
    if isinstance(node, dict):
        if HIST_KEYS <= set(node.keys()):
            check_histogram(path, where, node)
            found.append(where)
            return
        for key, child in node.items():
            walk(path, f"{where}.{key}", child, found)
    elif isinstance(node, list):
        for i, child in enumerate(node):
            walk(path, f"{where}[{i}]", child, found)


def check_file(path):
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            fail(f"{path}: not valid JSON: {e}")
    if doc.get("schema") != SCHEMA:
        fail(f"{path}: missing or wrong schema tag (want {SCHEMA!r}, "
             f"got {doc.get('schema')!r})")
    if not isinstance(doc.get("bench"), str) or not doc["bench"]:
        fail(f"{path}: missing bench name")
    found = []
    walk(path, "$", doc, found)
    if not found:
        fail(f"{path}: no embedded latency histograms found")
    print(f"{path}: ok ({doc['bench']}, {len(found)} histogram(s))")


def main(argv):
    if len(argv) < 2:
        fail("usage: check_bench_schema.py BENCH.json [...]")
    for path in argv[1:]:
        check_file(path)


if __name__ == "__main__":
    main(sys.argv)
