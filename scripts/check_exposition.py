#!/usr/bin/env python3
"""Validate a Prometheus text exposition scraped from `--metrics-listen`.

Usage: check_exposition.py EXPOSITION.txt

The file is a `GET /metrics` body (text format 0.0.4) from a
`ccn serve --metrics-listen` endpoint (the router's endpoint exports
`ccn_route_*` families instead and is not covered by the presence
checks here). Checks, failing on the first violation:

- every line is a `# TYPE` comment or a `series value` sample with a
  finite, non-negative value;
- every `# TYPE ... histogram` family is internally consistent: bucket
  upper bounds strictly ascend, cumulative counts are monotone
  non-decreasing, the terminal bucket is `+Inf`, `_count` equals the
  `+Inf` bucket, and `_sum` is present;
- every op / stage histogram of the serve registry is exported
  (`ccn_op_<op>_ns`, `ccn_stage_<stage>_ns`), as are the fixed counters
  (`ccn_<counter>_total`) and the windowed gauges
  (`ccn_window_<name>{window="1s"|"10s"|"60s"}`).

Stdlib only; exits non-zero with a message naming the offending line.
"""

import math
import sys

# the serve registry's pre-registered families (obs::names)
OPS = [
    "open",
    "step",
    "step_batch",
    "predict",
    "snapshot",
    "restore",
    "park",
    "warm",
    "close",
    "stats",
    "metrics",
    "ping",
]
STAGES = [
    "queue_wait",
    "step_scalar",
    "step_batched",
    "store_append",
    "store_load",
    "store_compact",
    "transport_read",
    "transport_decode",
    "transport_write",
]
COUNTERS = [
    "transport.err_decode",
    "transport.err_oversize",
    "transport.err_ghost_id",
    "transport.err_io",
    "trace.dropped",
]
WINDOWS = ["ops", "steps", "parks", "warms", "trace.dropped"]
WINDOW_LABELS = ["1s", "10s", "60s"]


def fail(msg):
    print(f"check_exposition: {msg}", file=sys.stderr)
    sys.exit(1)


def sanitize(name):
    return "".join(c if c.isalnum() else "_" for c in name)


def parse(path):
    """Return (types, samples): declared metric kinds and an ordered
    list of (series, value) pairs."""
    types = {}
    samples = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line.strip():
                continue
            if line.startswith("#"):
                parts = line.split()
                if len(parts) != 4 or parts[1] != "TYPE":
                    fail(f"{path}:{lineno}: unrecognized comment: {line}")
                types[parts[2]] = parts[3]
                continue
            if " " not in line:
                fail(f"{path}:{lineno}: sample without a value: {line}")
            series, raw = line.rsplit(" ", 1)
            try:
                value = float(raw)
            except ValueError:
                fail(f"{path}:{lineno}: non-numeric value: {line}")
            if not math.isfinite(value) or value < 0:
                fail(f"{path}:{lineno}: value must be finite and >= 0: {line}")
            samples.append((series, value))
    if not samples:
        fail(f"{path}: no samples")
    return types, samples


def bucket_bound(series, base):
    """The `le` bound of a `<base>_bucket{le="..."}` series, else None."""
    prefix = f'{base}_bucket{{le="'
    if not (series.startswith(prefix) and series.endswith('"}')):
        return None
    le = series[len(prefix):-2]
    return math.inf if le == "+Inf" else float(le)


def check_histogram(path, base, samples):
    buckets = []
    count = None
    has_sum = False
    for series, value in samples:
        le = bucket_bound(series, base)
        if le is not None:
            buckets.append((le, value))
        elif series == f"{base}_count":
            count = value
        elif series == f"{base}_sum":
            has_sum = True
    if not buckets:
        fail(f"{path}: {base}: no _bucket series")
    for (lo_le, lo_n), (hi_le, hi_n) in zip(buckets, buckets[1:]):
        if hi_le <= lo_le:
            fail(f"{path}: {base}: bucket bounds must ascend "
                 f"({hi_le} after {lo_le})")
        if hi_n < lo_n:
            fail(f"{path}: {base}: cumulative counts must be monotone "
                 f"({hi_n} after {lo_n})")
    if buckets[-1][0] != math.inf:
        fail(f"{path}: {base}: terminal bucket must be +Inf")
    if count is None:
        fail(f"{path}: {base}: missing _count")
    if not has_sum:
        fail(f"{path}: {base}: missing _sum")
    if buckets[-1][1] != count:
        fail(f"{path}: {base}: _count {count} != +Inf bucket "
             f"{buckets[-1][1]}")


def main(argv):
    if len(argv) != 2:
        fail("usage: check_exposition.py EXPOSITION.txt")
    path = argv[1]
    types, samples = parse(path)
    series_names = {s for s, _ in samples}

    histograms = [name for name, kind in types.items() if kind == "histogram"]
    for base in histograms:
        check_histogram(path, base, samples)

    for op in OPS:
        base = f"ccn_op_{sanitize(op)}_ns"
        if types.get(base) != "histogram":
            fail(f"{path}: missing op histogram {base}")
    for stage in STAGES:
        base = f"ccn_stage_{sanitize(stage)}_ns"
        if types.get(base) != "histogram":
            fail(f"{path}: missing stage histogram {base}")
    for counter in COUNTERS:
        base = f"ccn_{sanitize(counter)}_total"
        if types.get(base) != "counter" or base not in series_names:
            fail(f"{path}: missing counter {base}")
    for window in WINDOWS:
        base = f"ccn_window_{sanitize(window)}"
        if types.get(base) != "gauge":
            fail(f"{path}: missing window gauge {base}")
        for label in WINDOW_LABELS:
            series = f'{base}{{window="{label}"}}'
            if series not in series_names:
                fail(f"{path}: missing window sample {series}")

    print(f"{path}: ok ({len(histograms)} histogram(s), "
          f"{len(samples)} sample(s))")


if __name__ == "__main__":
    main(sys.argv)
