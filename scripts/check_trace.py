#!/usr/bin/env python3
"""Validate `ccn serve`/`ccn route` JSONL traces, and optionally the
reply stream of the smoke session that produced one, or the join
between a router trace and a backend trace.

Usage: check_trace.py TRACE.jsonl [REPLIES.jsonl]
       check_trace.py --join ROUTER.jsonl BACKEND.jsonl

Trace: every line must parse as one JSON object carrying ts_ns, op,
dur_ns, and ok; timestamps and durations must be non-negative (no
monotonicity requirement — concurrent transports may interleave events
out of order); at least one event must be present. Correlation fields
(trace_id, span_id, parent_span_id), when present, must be non-empty
strings of at most 64 ASCII alphanumeric-or-dash characters.

Replies (when given): every reply line must be ok:true, and the last
`metrics` reply — recognized by its ops/stages blocks — must cover all
nine session ops of the protocol.

--join: both files are validated as traces, then joined on trace_id.
Every router event that records a `backend` label (i.e. the op was
actually forwarded; router-local ops and failed forwards carry none)
must have at least one backend event with the same trace_id, every
matched backend event carrying a parent_span_id must name the router
event's span_id, and at least one pair must join. Assumes the backend
traced at sample rate 1 and that BACKEND.jsonl is the trace of the
backend the events were forwarded to.

Stdlib only; exits non-zero with a message naming the offending line on
the first violation.
"""

import json
import sys

CORRELATION_KEYS = ("trace_id", "span_id", "parent_span_id")
MAX_WIRE_ID_LEN = 64

NINE_OPS = [
    "open",
    "step",
    "step_batch",
    "predict",
    "snapshot",
    "restore",
    "park",
    "warm",
    "close",
]


def fail(msg):
    print(f"check_trace: {msg}", file=sys.stderr)
    sys.exit(1)


def valid_wire_id(value):
    return (
        isinstance(value, str)
        and 0 < len(value) <= MAX_WIRE_ID_LEN
        and all(c.isascii() and (c.isalnum() or c == "-") for c in value)
    )


def check_trace(path):
    events = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"{path}:{lineno}: not valid JSON: {e}")
            for key in ("ts_ns", "op", "dur_ns", "ok"):
                if key not in event:
                    fail(f"{path}:{lineno}: event missing {key!r}: {line}")
            if event["ts_ns"] < 0 or event["dur_ns"] < 0:
                fail(f"{path}:{lineno}: negative timestamp or duration: {line}")
            if not isinstance(event["op"], str):
                fail(f"{path}:{lineno}: op must be a string: {line}")
            if not isinstance(event["ok"], bool):
                fail(f"{path}:{lineno}: ok must be a bool: {line}")
            for key in CORRELATION_KEYS:
                if key in event and not valid_wire_id(event[key]):
                    fail(f"{path}:{lineno}: {key} must be a non-empty "
                         f"string of <= {MAX_WIRE_ID_LEN} alphanumeric-or-"
                         f"dash characters: {line}")
            events.append(event)
    if not events:
        fail(f"{path}: no trace events")
    print(f"{path}: ok ({len(events)} event(s))")
    return events


def check_join(router_path, backend_path):
    router_events = check_trace(router_path)
    backend_events = check_trace(backend_path)
    by_trace = {}
    for event in backend_events:
        if "trace_id" in event:
            by_trace.setdefault(event["trace_id"], []).append(event)
    joined = 0
    for event in router_events:
        if "trace_id" not in event:
            continue
        trace_id = event["trace_id"]
        children = by_trace.get(trace_id)
        if not children:
            # a router-local op (ping/stats/metrics) or a failed forward
            # legitimately has no backend child — recognized by the
            # absent backend label
            if "backend" in event:
                fail(f"{router_path}: trace {trace_id!r} ({event['op']}) "
                     f"was forwarded to {event['backend']} but has no "
                     f"backend event in {backend_path}")
            continue
        span = event.get("span_id")
        for child in children:
            parent = child.get("parent_span_id")
            if span is not None and parent is not None and parent != span:
                fail(f"{backend_path}: trace {trace_id!r}: parent_span_id "
                     f"{parent!r} does not name the router span {span!r}")
        joined += 1
    if joined == 0:
        fail(f"{router_path} x {backend_path}: no correlated pair joined "
             f"on trace_id")
    print(f"join: ok ({joined} router event(s) joined to "
          f"{backend_path})")


def check_replies(path):
    metrics = None
    replies = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                reply = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"{path}:{lineno}: not valid JSON: {e}")
            if reply.get("ok") is not True:
                fail(f"{path}:{lineno}: reply not ok: {line}")
            if "ops" in reply and "stages" in reply:
                metrics = (lineno, reply)
            replies += 1
    if replies == 0:
        fail(f"{path}: no replies")
    if metrics is None:
        fail(f"{path}: no metrics reply in the smoke session")
    lineno, reply = metrics
    for op in NINE_OPS:
        if op not in reply["ops"]:
            fail(f"{path}:{lineno}: metrics reply missing op {op!r}")
    print(f"{path}: ok ({replies} replies, metrics covers all nine ops)")


def main(argv):
    if len(argv) == 4 and argv[1] == "--join":
        check_join(argv[2], argv[3])
        return
    if len(argv) < 2 or len(argv) > 3:
        fail("usage: check_trace.py TRACE.jsonl [REPLIES.jsonl] | "
             "check_trace.py --join ROUTER.jsonl BACKEND.jsonl")
    check_trace(argv[1])
    if len(argv) == 3:
        check_replies(argv[2])


if __name__ == "__main__":
    main(sys.argv)
