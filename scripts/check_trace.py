#!/usr/bin/env python3
"""Validate a `ccn serve --trace-file` JSONL trace, and optionally the
reply stream of the smoke session that produced it.

Usage: check_trace.py TRACE.jsonl [REPLIES.jsonl]

Trace: every line must parse as one JSON object carrying ts_ns, op,
dur_ns, and ok; timestamps and durations must be non-negative (no
monotonicity requirement — concurrent transports may interleave events
out of order); at least one event must be present.

Replies (when given): every reply line must be ok:true, and the last
`metrics` reply — recognized by its ops/stages blocks — must cover all
nine session ops of the protocol.

Stdlib only; exits non-zero with a message naming the offending line on
the first violation.
"""

import json
import sys

NINE_OPS = [
    "open",
    "step",
    "step_batch",
    "predict",
    "snapshot",
    "restore",
    "park",
    "warm",
    "close",
]


def fail(msg):
    print(f"check_trace: {msg}", file=sys.stderr)
    sys.exit(1)


def check_trace(path):
    events = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"{path}:{lineno}: not valid JSON: {e}")
            for key in ("ts_ns", "op", "dur_ns", "ok"):
                if key not in event:
                    fail(f"{path}:{lineno}: event missing {key!r}: {line}")
            if event["ts_ns"] < 0 or event["dur_ns"] < 0:
                fail(f"{path}:{lineno}: negative timestamp or duration: {line}")
            if not isinstance(event["op"], str):
                fail(f"{path}:{lineno}: op must be a string: {line}")
            if not isinstance(event["ok"], bool):
                fail(f"{path}:{lineno}: ok must be a bool: {line}")
            events += 1
    if events == 0:
        fail(f"{path}: no trace events")
    print(f"{path}: ok ({events} event(s))")


def check_replies(path):
    metrics = None
    replies = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                reply = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"{path}:{lineno}: not valid JSON: {e}")
            if reply.get("ok") is not True:
                fail(f"{path}:{lineno}: reply not ok: {line}")
            if "ops" in reply and "stages" in reply:
                metrics = (lineno, reply)
            replies += 1
    if replies == 0:
        fail(f"{path}: no replies")
    if metrics is None:
        fail(f"{path}: no metrics reply in the smoke session")
    lineno, reply = metrics
    for op in NINE_OPS:
        if op not in reply["ops"]:
            fail(f"{path}:{lineno}: metrics reply missing op {op!r}")
    print(f"{path}: ok ({replies} replies, metrics covers all nine ops)")


def main(argv):
    if len(argv) < 2 or len(argv) > 3:
        fail("usage: check_trace.py TRACE.jsonl [REPLIES.jsonl]")
    check_trace(argv[1])
    if len(argv) == 3:
        check_replies(argv[2])


if __name__ == "__main__":
    main(sys.argv)
