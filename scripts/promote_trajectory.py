#!/usr/bin/env python3
"""Promote CI-measured bench snapshots into the committed trajectory.

Usage: promote_trajectory.py ARTIFACT_DIR TRAJECTORY_DIR

ARTIFACT_DIR is a downloaded `trajectory-snapshot` CI artifact (e.g.
`gh run download -n trajectory-snapshot -D /tmp/snap`): dated
`BENCH_YYYYMMDD_<bench>.json` files in the ccn.bench.v1 schema, each
stamped by `append_trajectory.py --copy-to` from a run that already
passed the regression gate. This script validates each snapshot and
copies it into TRAJECTORY_DIR, then deletes any *floor seed* the
measured snapshot supersedes — a floor seed is a hand-written
conservative baseline whose top-level `note` contains "floor seed",
committed before the first CI run so the gate has something to compare
against. After promotion, `git add`/commit TRAJECTORY_DIR: the next CI
run gates against real measured numbers instead of the floor.

A measured snapshot never overwrites a *newer* committed snapshot of
the same bench (lexicographic name order = date order), and a floor
seed in ARTIFACT_DIR is refused — the artifact must carry measurements.

Stdlib only; exits non-zero naming the offending file on failure.
"""

import json
import os
import shutil
import sys

SCHEMA = "ccn.bench.v1"


def fail(msg):
    print(f"promote_trajectory: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")
    if doc.get("schema") != SCHEMA:
        fail(f"{path}: missing or wrong schema tag (want {SCHEMA!r}, "
             f"got {doc.get('schema')!r})")
    if not isinstance(doc.get("bench"), str) or not doc["bench"]:
        fail(f"{path}: missing bench name")
    return doc


def is_floor_seed(doc):
    return "floor seed" in str(doc.get("note", ""))


def snapshots(dir_path):
    return sorted(
        name for name in os.listdir(dir_path)
        if name.startswith("BENCH_") and name.endswith(".json")
    )


def main(argv):
    if len(argv) != 3:
        fail("usage: promote_trajectory.py ARTIFACT_DIR TRAJECTORY_DIR")
    art_dir, traj_dir = argv[1], argv[2]
    incoming = snapshots(art_dir)
    if not incoming:
        fail(f"{art_dir}: no BENCH_*.json snapshots to promote")

    committed = {}  # bench -> [(name, is_floor)] in date order
    for name in snapshots(traj_dir):
        doc = load(os.path.join(traj_dir, name))
        committed.setdefault(doc["bench"], []).append(
            (name, is_floor_seed(doc)))

    promoted = 0
    for name in incoming:
        src = os.path.join(art_dir, name)
        doc = load(src)
        if is_floor_seed(doc):
            fail(f"{src}: is itself a floor seed; promote measured "
                 f"snapshots only")
        bench = doc["bench"]
        newer = [n for n, _ in committed.get(bench, []) if n > name]
        if newer:
            print(f"promote_trajectory: skip {name}: {newer[-1]} is newer")
            continue
        shutil.copyfile(src, os.path.join(traj_dir, name))
        print(f"promote_trajectory: promoted {name} ({bench})")
        promoted += 1
        # the measured snapshot supersedes any committed floor seed
        for old, floor in committed.get(bench, []):
            if floor and old != name:
                os.remove(os.path.join(traj_dir, old))
                print(f"promote_trajectory: removed superseded floor "
                      f"seed {old}")
        committed[bench] = [(name, False)]

    if promoted == 0:
        fail("nothing promoted (every artifact snapshot was stale)")
    print(f"promote_trajectory: ok ({promoted} snapshot(s) promoted; "
          f"commit {traj_dir} to tighten the gate)")


if __name__ == "__main__":
    main(sys.argv)
